//! The named fault-scenario bank: every scenario here comes from
//! `peersdb::sim::bank` (shared with the self-timing
//! `benches/sim_scale.rs`), runs through the declarative harness in
//! `peersdb::sim::scenario`, passes the full set of cluster-wide
//! invariants (log convergence, quorum safety, DHT routing health, block
//! availability), and is verified to be byte-identical on replay: same
//! seed, same `SimStats`, same digest. That replay check is the
//! determinism guard for the zero-copy block plane — if the refactored
//! message path influenced behavior at all, two runs from one seed would
//! diverge and every test here would fail.
//!
//! These are the reproducible versions of the conditions the paper's
//! evaluation (and the collaborative-optimization line of work it builds
//! on) cares about: shared performance data must survive partitions,
//! churn, regional failure, load spikes, and malicious contributors.

use peersdb::sim::bank;
use peersdb::sim::scenario;
use peersdb::util::time::Duration;
use std::collections::BTreeSet;

// ---------------------------------------------------------------------------
// 1. Network partition during active contribution traffic
// ---------------------------------------------------------------------------

#[test]
fn scenario_partition_heals_and_converges() {
    let report = scenario::run_replayed(&bank::partition_heal()).expect("partition scenario");
    assert_eq!(report.contributions, 5);
    assert_eq!(report.checkpoints, 1);
    // The partition actually dropped traffic — the fault was real.
    assert!(report.stats.msgs_dropped_blocked > 0, "partition never bit");
}

// ---------------------------------------------------------------------------
// 2. Regional outage and recovery
// ---------------------------------------------------------------------------

#[test]
fn scenario_regional_outage_recovers() {
    let report =
        scenario::run_replayed(&bank::regional_outage()).expect("regional outage scenario");
    assert_eq!(report.contributions, 4);
    // Offline peers drop deliveries; the outage was observable.
    assert!(report.stats.msgs_dropped_offline > 0, "outage never bit");
}

// ---------------------------------------------------------------------------
// 3. Crash/restart churn while data flows
// ---------------------------------------------------------------------------

#[test]
fn scenario_crash_restart_churn() {
    let report = scenario::run_replayed(&bank::crash_churn()).expect("churn scenario");
    assert_eq!(report.contributions, 5);
    assert_eq!(report.checkpoints, 1);
}

// ---------------------------------------------------------------------------
// 4. Flash-crowd join: the cluster doubles mid-run
// ---------------------------------------------------------------------------

#[test]
fn scenario_flash_crowd_syncs_history() {
    let report = scenario::run_replayed(&bank::flash_crowd()).expect("flash crowd scenario");
    assert_eq!(report.peers, 10, "joiners must be cluster members");
    assert_eq!(report.contributions, 3);
    // Convergence at quiesce (checked by the harness) implies the
    // joiners replicated history contributed *before* they existed.
}

// ---------------------------------------------------------------------------
// 5. Root-peer CPU strain (the paper's §IV-A artifact, injected)
// ---------------------------------------------------------------------------

#[test]
fn scenario_root_cpu_strain_inflates_but_converges() {
    // Baseline vs the same schedule under a 5000× root CPU slowdown
    // (≈150 ms per message at the root, serialized — the paper's
    // root-peer strain artifact, exaggerated until unmistakable).
    let (nominal, ncluster) = scenario::run_cluster(&bank::cpu_nominal()).expect("nominal");
    let (strained, scluster) = scenario::run_cluster(&bank::cpu_strain()).expect("strained");
    assert_eq!(nominal.contributions, strained.contributions);
    // The strained root replicates each file much later: every message
    // it processes costs 5000× and queues behind the rest.
    let repl_mean = |c: &peersdb::sim::Cluster<peersdb::peersdb::Node>| {
        c.node(0)
            .metrics
            .summary("replication_ms")
            .map(|s| s.mean())
            .unwrap_or(0.0)
    };
    let (m_nom, m_str) = (repl_mean(&ncluster), repl_mean(&scluster));
    assert!(m_nom > 0.0, "root never replicated in the baseline");
    assert!(
        m_str > m_nom * 1.5,
        "root replication under strain ({m_str:.0} ms) not slower than nominal ({m_nom:.0} ms)"
    );
    // Replay determinism for the strained schedule.
    let replay = scenario::run(&bank::cpu_strain()).expect("replay");
    assert_eq!(strained, replay, "cpu-strain scenario not deterministic");
}

// ---------------------------------------------------------------------------
// 6. Byzantine validator: a lying minority cannot poison verdicts
// ---------------------------------------------------------------------------

#[test]
fn scenario_byzantine_minority_cannot_poison_quorum() {
    use peersdb::stores::documents::Verdict;

    let sc = bank::byzantine_minority();
    let (report, cluster) = scenario::run_cluster(&sc).expect("byzantine scenario");
    // Replay determinism (run_cluster doesn't go through run_replayed).
    let report2 = scenario::run(&sc).expect("replay");
    assert_eq!(report, report2, "byzantine scenario not deterministic");

    // No honest node may hold a wrong verdict, and each file must have
    // been judged (correctly) by several honest peers.
    for (cid, corrupt) in &report.cids {
        let expect = if *corrupt { Verdict::Invalid } else { Verdict::Valid };
        let wrong = if *corrupt { Verdict::Valid } else { Verdict::Invalid };
        let mut correct = 0;
        for i in 0..cluster.len() {
            if sc.byzantine.contains(&i) {
                continue;
            }
            match cluster.node(i).validations.verdict(cid) {
                Some(v) if v == wrong => {
                    panic!("honest node {i} adopted the byzantine verdict for {cid:?}")
                }
                Some(v) if v == expect => correct += 1,
                _ => {}
            }
        }
        assert!(correct >= 3, "only {correct} honest nodes judged {cid:?} (corrupt={corrupt})");
    }
}

// ---------------------------------------------------------------------------
// 7. Kitchen sink: loss spike + flapping links + churn, one schedule
// ---------------------------------------------------------------------------

#[test]
fn scenario_kitchen_sink_survives_everything() {
    let report = scenario::run_replayed(&bank::kitchen_sink()).expect("kitchen sink scenario");
    assert_eq!(report.contributions, 4);
    assert!(report.stats.msgs_dropped_loss > 0, "loss spike never bit");
}

/// Mean recorded `bootstrap_ms` across nodes `lo..hi` (panics if a node
/// in the slice never finished bootstrapping — the harness invariants
/// should have caught that first).
fn wave_mean(cluster: &peersdb::sim::Cluster<peersdb::peersdb::Node>, lo: usize, hi: usize) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for i in lo..hi {
        let s = cluster
            .node(i)
            .metrics
            .summary("bootstrap_ms")
            .unwrap_or_else(|| panic!("node {i} recorded no bootstrap_ms"));
        sum += s.mean();
        n += 1;
    }
    sum / n as f64
}

// ---------------------------------------------------------------------------
// 8. Multi-region scale-out: 100 peers, three staggered flash crowds —
//    paper experiment 2 at 10× (the ROADMAP headline this PR lands).
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "100-peer DES run needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_multi_region_scale_out() {
    let sc = bank::multi_region_scale_out();
    let (report, cluster) = scenario::run_cluster(&sc).expect("scale-out scenario");
    // Replay determinism at full scale.
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "scale-out scenario not deterministic");

    // Shape: ≥ 100 peers spread over ≥ 3 regions.
    assert!(report.peers >= 100, "only {} peers", report.peers);
    let regions: BTreeSet<_> = (0..cluster.len()).map(|i| cluster.region_of(i)).collect();
    assert!(regions.len() >= 3, "only {} regions", regions.len());
    assert_eq!(report.contributions, 6);
    assert_eq!(report.checkpoints, 1);

    // Bootstrap-time scaling: every wave of joiners completed bootstrap
    // (the quiesce invariants already insist on that), and the time to
    // bootstrap stays bounded as the cluster quadruples and the history
    // grows — the paper's experiment-2 question at 10× its cluster size.
    let wave = bank::SCALE_OUT_WAVE;
    let w1 = wave_mean(&cluster, wave, 2 * wave);
    let w2 = wave_mean(&cluster, 2 * wave, 3 * wave);
    let w3 = wave_mean(&cluster, 3 * wave, 4 * wave);
    assert!(w1 > 0.0 && w2 > 0.0 && w3 > 0.0, "waves must record bootstrap times");
    // Bounded degradation: the last wave joins a 75-peer cluster holding
    // the full history, yet must bootstrap within the same order of
    // magnitude as the first (generous constants absorb flash-crowd
    // queueing noise, not a scaling blow-up).
    assert!(
        w3 < w1 * 50.0 + 30_000.0,
        "wave-3 bootstrap ({w3:.0} ms) blew up vs wave 1 ({w1:.0} ms)"
    );
    assert!(w3 < 180_000.0, "wave-3 bootstrap took {w3:.0} ms (> 3 virtual minutes)");
    println!(
        "scale-out bootstrap means: wave1 {w1:.0} ms, wave2 {w2:.0} ms, wave3 {w3:.0} ms \
         (peers={}, end={}, events={})",
        report.peers, report.end, report.stats.events_processed
    );
}

// ---------------------------------------------------------------------------
// 9. Asymmetric half-open region: 25 joiners can reach the core but
//    cannot be reached — the directional link-state plane headline.
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "35-peer DES run needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_asymmetric_region_halfopen() {
    let sc = bank::asymmetric_region_halfopen();
    let (report, cluster) = scenario::run_cluster(&sc).expect("half-open scenario");
    // Replay determinism of the directional fault path.
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "half-open scenario not deterministic");

    assert_eq!(report.peers, bank::HALFOPEN_CORE + bank::HALFOPEN_REGION);
    assert_eq!(report.contributions, 4);
    assert_eq!(report.checkpoints, 1);
    // The half-open direction really dropped traffic (JoinAcks, replies).
    assert!(report.stats.msgs_dropped_blocked > 0, "half-open link never bit");

    // Bounded staleness: every region joiner eventually bootstrapped
    // (the harness invariants insist on it), but only after the heal —
    // its bootstrap time must contain the ~55 s half-open stall, while
    // the unaffected core bootstrapped in seconds during warmup.
    let region_lo = bank::HALFOPEN_CORE;
    let region_hi = bank::HALFOPEN_CORE + bank::HALFOPEN_REGION;
    let region_mean = wave_mean(&cluster, region_lo, region_hi);
    let core_mean = wave_mean(&cluster, 1, bank::HALFOPEN_CORE);
    assert!(
        region_mean >= 30_000.0,
        "region bootstrap mean {region_mean:.0} ms does not reflect the half-open stall"
    );
    assert!(
        region_mean > core_mean,
        "half-open region ({region_mean:.0} ms) not slower than core ({core_mean:.0} ms)"
    );
    println!(
        "half-open bootstrap means: core {core_mean:.0} ms, region {region_mean:.0} ms \
         ({} blocked drops)",
        report.stats.msgs_dropped_blocked
    );
}

// ---------------------------------------------------------------------------
// 10. Adversarial eclipse: forged DHT replies + half-open isolation own
//     the victim's view; the eclipse invariant certifies recovery.
// ---------------------------------------------------------------------------

#[test]
fn scenario_adversarial_eclipse_recovers() {
    let sc = bank::adversarial_eclipse();
    let (report, cluster) = scenario::run_cluster(&sc).expect("eclipse scenario");
    // Replay determinism (run_cluster doesn't go through run_replayed).
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "eclipse scenario not deterministic");

    assert_eq!(report.contributions, 5);
    assert_eq!(report.checkpoints, 1);
    // The attack actually ran: colluders served forged replies and the
    // victim's isolation dropped honest replies.
    let forged: u64 = bank::ECLIPSE_ATTACKERS
        .iter()
        .map(|&i| cluster.node(i).dht.replies_forged)
        .sum();
    assert!(forged > 0, "attackers never forged a reply");
    assert!(report.stats.msgs_dropped_blocked > 0, "victim isolation never bit");
    // Teardown hygiene: nobody is left forging, no link override leaks.
    for &i in &bank::ECLIPSE_ATTACKERS {
        assert!(!cluster.node(i).dht.is_forging());
    }
    assert_eq!(cluster.overridden_links(), 0);
    // The quiesce invariants already asserted eclipse recovery; make the
    // conclusion explicit here too.
    let ec = sc.invariants.eclipse.as_ref().unwrap();
    scenario::check_eclipse(&cluster, ec).expect("victim regained honest neighbors");
}

// ---------------------------------------------------------------------------
// 11. GC pressure: auto-pin off, repair is the only replication path; a
//     third of the cluster (the authors) unpins + GCs mid-run, and the
//     repair loop must re-replicate from the surviving holders.
// ---------------------------------------------------------------------------

/// Nodes holding the full file `cid` at quiesce.
fn holders_of(
    cluster: &peersdb::sim::Cluster<peersdb::peersdb::Node>,
    cid: &peersdb::cid::Cid,
) -> Vec<usize> {
    (0..cluster.len())
        .filter(|&i| peersdb::blockstore::chunker::has_file(&cluster.node(i).bs, cid))
        .collect()
}

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "long repair-loop DES run needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_gc_pressure_rereplicates() {
    let sc = bank::gc_pressure();
    let (report, cluster) = scenario::run_cluster(&sc).expect("gc-pressure scenario");
    // Replay determinism (run_cluster doesn't go through run_replayed).
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "gc-pressure scenario not deterministic");

    assert_eq!(report.contributions, 3);
    assert_eq!(report.checkpoints, 1);
    // The GC really destroyed data, and the repair loop really acted.
    for &i in &bank::GC_PRESSURE_DROPPERS {
        assert!(cluster.node(i).metrics.counter("blocks_gcd") > 0, "node {i} gc'd nothing");
        assert!(cluster.node(i).metrics.counter("bytes_gcd") > 0, "node {i} freed no bytes");
    }
    let repairs: u64 =
        (0..cluster.len()).map(|i| cluster.node(i).metrics.counter("repairs_triggered")).sum();
    assert!(repairs > 0, "no node ever triggered a repair");
    let refetches: u64 =
        (0..cluster.len()).map(|i| cluster.node(i).metrics.counter("repair_refetches")).sum();
    assert!(refetches > 0, "repair never re-fetched anything");

    for (k, &dropper) in bank::GC_PRESSURE_DROPPERS.iter().enumerate() {
        let (cid, _) = report.cids[k];
        let holders = holders_of(&cluster, &cid);
        // Availability recovered without the dropper (the harness
        // already asserted ≥ replication_target; make it explicit)…
        assert!(holders.len() >= 3, "{cid:?} on only {holders:?}");
        // …and deliberately dropped data is never resurrected on the
        // node that dropped it.
        assert!(
            !holders.contains(&dropper),
            "node {dropper} resurrected its deliberately dropped file {cid:?}"
        );
    }
}

#[test]
fn gc_pressure_data_loss_is_detected_without_repair() {
    // Negative control: the same schedule with the repair loop switched
    // off from the first instant. Auto-pinning is off, so nobody ever
    // replicates the authors' files — when the authors unpin + GC, the
    // data is gone from every live node and the availability invariant
    // must fire. This proves the scenario detects real data loss rather
    // than vacuously passing. (Short quiesce: nothing will heal it.)
    use peersdb::sim::scenario::{Fault, TimedFault};

    let mut sc = bank::gc_pressure();
    sc.events.insert(
        0,
        TimedFault { at: Duration::ZERO, fault: Fault::SetRepair { on: false } },
    );
    sc.quiesce = Duration::from_secs(120);
    sc.quiesce_poll = Duration::ZERO;
    let err = scenario::run(&sc).expect_err("destroyed data must trip the invariant");
    assert!(err.contains("data loss"), "wrong failure: {err}");
}

// ---------------------------------------------------------------------------
// 12. Half-open holders: the surviving replicas' announces arrive but
//     Wants to them vanish — repair must route around the phantoms.
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "long repair-loop DES run needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_halfopen_holders_routes_around() {
    let sc = bank::halfopen_holders();
    let (report, cluster) = scenario::run_cluster(&sc).expect("half-open holders scenario");
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "half-open holders scenario not deterministic");

    assert_eq!(report.contributions, 2);
    assert_eq!(report.checkpoints, 2);
    // The half-open boundary really dropped traffic (Wants, queries).
    assert!(report.stats.msgs_dropped_blocked > 0, "half-open links never bit");
    let repairs: u64 =
        (0..cluster.len()).map(|i| cluster.node(i).metrics.counter("repairs_triggered")).sum();
    assert!(repairs > 0, "no node ever triggered a repair");

    for (k, &dropper) in bank::HALFOPEN_DROPPERS.iter().enumerate() {
        let (cid, _) = report.cids[k];
        let holders = holders_of(&cluster, &cid);
        assert!(holders.len() >= 3, "{cid:?} on only {holders:?}");
        assert!(
            !holders.contains(&dropper),
            "node {dropper} resurrected its deliberately dropped file {cid:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// 13. Defended eclipse: the same attack with NO recovery tail —
//     disjoint-path lookups + distance-verified routing updates (with
//     the pending_verify re-verification tier) must keep / restore the
//     victim's honest view on their own.
// ---------------------------------------------------------------------------

#[test]
fn scenario_defended_eclipse_survives_without_recovery_tail() {
    use peersdb::sim::harness;

    let sc = bank::defended_eclipse();
    let (report, cluster) = scenario::run_cluster(&sc).expect("defended eclipse scenario");
    // Replay determinism (run_cluster doesn't go through run_replayed).
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "defended eclipse scenario not deterministic");

    assert_eq!(report.contributions, 3);
    assert_eq!(report.checkpoints, 1);
    // The attack genuinely ran: forged replies were served and the
    // victim's isolation dropped honest traffic.
    let forged: u64 = bank::ECLIPSE_ATTACKERS
        .iter()
        .map(|&i| cluster.node(i).dht.replies_forged)
        .sum();
    assert!(forged > 0, "attackers never forged a reply");
    assert!(report.stats.msgs_dropped_blocked > 0, "victim isolation never bit");
    // The defenses genuinely engaged, and the report carries the same
    // totals the harness helper reads off the cluster.
    let (paths, rejected, quarantined) = harness::dht_defense_totals(&cluster);
    assert_eq!(
        (paths, rejected, quarantined),
        (
            report.stats.lookup_paths_started,
            report.stats.closer_peers_rejected,
            report.stats.unverified_peers_quarantined,
        ),
        "report stats diverged from the cluster's engine counters"
    );
    assert!(paths > 0, "no disjoint-path lookup ever started");
    assert!(rejected > 0, "distance verification never rejected a candidate");
    assert!(quarantined > 0, "no hearsay peer was ever quarantined");
    // The quiesce invariants already asserted the EclipseInvariant.
    // The schedule contains no healed recovery tail AND shuts the
    // repair loop down before the attack window closes, so during the
    // quiesce the victim starts no lookups at all — there is no hearsay
    // channel for an undefended table to rebuild through. The
    // `pending_verify` re-verification pings are the only mechanism
    // that can have restored the honest view. Make that explicit.
    let ec = sc.invariants.eclipse.as_ref().unwrap();
    scenario::check_eclipse(&cluster, ec).expect("victim kept honest neighbors on its own");
    // The ROADMAP's second probe angle: the victim's availability-repair
    // probes (exhaustive `find_providers_full` walks, every cycle of
    // which lands inside the attack window) never observed an empty
    // provider set — the attack lies *upward* (forged records), so the
    // availability view degrades to attacker-poisoned, never to dark.
    // This pins the probe trace the scenario exists to record; the
    // defense claim above rests on the eclipse invariant, not on this.
    let probes = cluster
        .node(bank::ECLIPSE_VICTIM)
        .metrics
        .summary("repair_providers_found")
        .expect("victim never ran a repair probe");
    assert!(!probes.is_empty());
    assert!(
        probes.min() > 0.0,
        "a victim provider-count probe went dark (min of {} samples hit zero)",
        probes.len()
    );
}

#[test]
fn defended_eclipse_defense_matters() {
    // Negative control, mirroring
    // `eclipse_attack_is_detected_without_recovery_window`: the exact
    // `bank::defended_eclipse` schedule with the defenses stripped
    // (single-path lookups, hearsay admitted freely) and no quiesce to
    // heal in. The victim must end fully eclipsed — proving the
    // defended scenario passes because of the defenses, not because the
    // truncated attack got weaker.
    let mut sc = bank::defended_eclipse();
    sc.cfg.dht.lookup_paths = 1;
    sc.cfg.dht.verify_peers = false;
    sc.quiesce = Duration::ZERO;
    sc.quiesce_poll = Duration::ZERO;
    let err = scenario::run(&sc).expect_err("undefended victim must fail the invariant");
    assert!(err.contains("eclipse"), "wrong failure: {err}");
}

// ---------------------------------------------------------------------------
// 14/15. Slow-peer drag: the quality scheduler samples the 10×-slow
//     author once and routes the remaining stripes around it; the
//     round-robin control keeps dealing to it. Same schedule, both
//     striped — the gap is the scheduler's doing.
// ---------------------------------------------------------------------------

/// Worst joiner time-to-replicate (ms) across the flash-crowd joiners
/// (indices `STRIPE_PEERS..`) — the striped-transfer scenarios' figure
/// of merit.
fn joiner_repl_max(cluster: &peersdb::sim::Cluster<peersdb::peersdb::Node>) -> f64 {
    let mut worst = 0.0f64;
    for i in bank::STRIPE_PEERS..cluster.len() {
        let s = cluster
            .node(i)
            .metrics
            .summary("replication_ms")
            .unwrap_or_else(|| panic!("joiner {i} never replicated"));
        worst = worst.max(s.max());
    }
    worst
}

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "two ~10 MB striped-transfer DES runs need the release profile; CI runs `cargo test --release`"
)]
fn scenario_slow_peer_drag_quality_beats_round_robin() {
    let q = bank::slow_peer_drag();
    let (q_report, q_cluster) = scenario::run_cluster(&q).expect("quality drag scenario");
    // Replay determinism of the quality-scheduler path (run_cluster
    // doesn't go through run_replayed).
    let replay = scenario::run(&q).expect("replay");
    assert_eq!(q_report, replay, "slow-peer-drag not deterministic");

    let (rr_report, rr_cluster) =
        scenario::run_cluster(&bank::slow_peer_drag_rr()).expect("round-robin control");

    assert_eq!(q_report.contributions, 1);
    assert_eq!(rr_report.contributions, 1);
    // Both runs genuinely striped chunks across providers.
    assert!(q_report.stats.chunks_striped > 0, "quality run never striped");
    assert!(rr_report.stats.chunks_striped > 0, "control run never striped");

    // The joiners fetch behind a 10×-slow link to the author. Quality
    // pays roughly one slow round-trip (the sample that inflates the
    // author's EWMA); round-robin pays one per dealt chunk, all the way
    // down the file. Same schedule, so the gap is the scheduler's.
    let (q_ms, rr_ms) = (joiner_repl_max(&q_cluster), joiner_repl_max(&rr_cluster));
    assert!(q_ms > 0.0 && rr_ms > 0.0, "joiners must have replicated");
    assert!(
        q_ms + 100.0 < rr_ms,
        "quality joiners ({q_ms:.0} ms) not measurably faster than round-robin ({rr_ms:.0} ms)"
    );
    println!(
        "slow-peer drag joiner worst-case replication: quality {q_ms:.0} ms, \
         round-robin {rr_ms:.0} ms (striped {} vs {})",
        q_report.stats.chunks_striped, rr_report.stats.chunks_striped
    );
}

// ---------------------------------------------------------------------------
// 16. Provider death mid-transfer: a dead replica's provider record
//     outlives it; stripes assigned to the corpse must time out, get
//     reassigned to live providers, and the fetch must still complete.
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "~10 MB striped-transfer DES run needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_provider_death_midtransfer_reassigns() {
    use peersdb::sim::harness;

    let sc = bank::provider_death_midtransfer();
    let (report, cluster) = scenario::run_cluster(&sc).expect("provider-death scenario");
    // Replay determinism of the reassignment path.
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "provider-death scenario not deterministic");

    assert_eq!(report.contributions, 1);
    assert_eq!(report.checkpoints, 1);
    // The scheduler striped, a stripe landed on the corpse, and the
    // chunk moved on to a live provider.
    assert!(report.stats.chunks_striped > 0, "nothing was ever striped");
    assert!(report.stats.transfer_reassignments > 0, "no chunk was ever reassigned");
    // The report's totals are exactly the cluster's metric totals (the
    // same identity the defended-eclipse test pins for the DHT trio).
    let (striped, reassigned) = harness::transfer_totals(&cluster);
    assert_eq!(
        (striped, reassigned),
        (report.stats.chunks_striped, report.stats.transfer_reassignments),
        "report stats diverged from the cluster's metric totals"
    );
    // The joiner holds the whole file at quiesce — reassignment finished
    // the fetch (the fetch-stall + availability invariants already
    // insisted; make it explicit).
    let (cid, _) = report.cids[0];
    assert!(
        peersdb::blockstore::chunker::has_file(&cluster.node(bank::STRIPE_PEERS).bs, &cid),
        "joiner never completed the striped fetch"
    );
}

// ---------------------------------------------------------------------------
// 17. Delayed honest majority: a byzantine-majority sample answers fast,
//     the honest verdicts crawl in after the vote timeout. The grace
//     extension must hold the vote open until the quorum completes
//     honestly instead of force-tallying the unanimous lie.
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "long delayed-quorum DES run needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_delayed_honest_majority_grace_rescues() {
    use peersdb::sim::harness;

    let sc = bank::delayed_honest_majority();
    let (report, cluster) = scenario::run_cluster(&sc).expect("delayed-honest-majority scenario");
    // Replay determinism of the grace-extension path.
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "delayed-honest-majority not deterministic");

    assert_eq!(report.contributions, 1);
    // The VerdictIntegrityInvariant already held at quiesce; pin the
    // counters that prove it held because the defense engaged, not
    // because the attack fizzled: the late joiner's vote expired short
    // of quorum (extended), and the grace window let the late honest
    // verdicts complete the tally the legacy timeout would have
    // force-decided from byzantine answers alone (rescued).
    assert_eq!(report.stats.false_verdicts_adopted, 0, "an adopted lie survived to quiesce");
    assert!(report.stats.votes_extended >= 1, "no vote ever entered the grace window");
    assert!(report.stats.votes_rescued_by_grace >= 1, "the grace window never rescued a vote");
    // The early all-answers-in first wave still force-tallies as ever —
    // grace only defers votes with peers still outstanding.
    assert!(report.stats.votes_forced > 0, "first-wave votes never force-tallied");
    // The report's totals are exactly the cluster's metric totals (the
    // same identity the defended-eclipse and provider-death tests pin
    // for the DHT and transfer counter groups).
    let (forced, extended, rescued) = harness::quorum_totals(&cluster);
    assert_eq!(
        (forced, extended, rescued),
        (
            report.stats.votes_forced,
            report.stats.votes_extended,
            report.stats.votes_rescued_by_grace,
        ),
        "report stats diverged from the cluster's metric totals"
    );
    // The invariant's own audit, asserted directly: no honest node holds
    // a network-adopted verdict contradicting ground truth.
    assert_eq!(harness::false_verdicts(&cluster, &report.cids, &sc.byzantine), 0);
}

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "long delayed-quorum DES run needs the release profile; CI runs `cargo test --release`"
)]
fn delayed_honest_majority_lie_is_detected_without_grace() {
    // Negative control, mirroring `defended_eclipse_defense_matters`:
    // the exact bank schedule with the grace knob stripped back to the
    // legacy timeout. The byzantine-majority sample answers inside the
    // window, the honest verdicts are still in flight at expiry, and
    // the forced tally adopts the unanimous lie — the integrity
    // invariant must fire, proving the defended scenario passes because
    // of the grace window, not because the attack was toothless.
    let mut sc = bank::delayed_honest_majority();
    sc.cfg.quorum.timeout_grace = Duration::ZERO;
    sc.quiesce = Duration::from_secs(120);
    sc.quiesce_poll = Duration::ZERO;
    let err = scenario::run(&sc).expect_err("undefended voter must adopt the lie");
    assert!(err.contains("verdict integrity"), "wrong failure: {err}");
    // The embedded audit count proves at least one lie was adopted.
    assert!(!err.contains("false_verdicts_adopted=0"), "invariant fired with a zero count: {err}");
}

#[test]
fn eclipse_attack_is_detected_without_recovery_window() {
    // The defense half of the eclipse scenario is the healed tail: links
    // reopen, forging stops, honest traffic repopulates the victim's
    // view. Strip that tail (keep only the attack window) and grant no
    // quiesce: the eclipse invariant must fire — i.e. the scenario
    // demonstrably *detects* a successful attack rather than vacuously
    // passing.
    let mut sc = bank::adversarial_eclipse();
    sc.events.retain(|e| e.at < Duration::from_secs(bank::ECLIPSE_HEAL_SECS));
    sc.quiesce = Duration::ZERO;
    sc.quiesce_poll = Duration::ZERO;
    let err = scenario::run(&sc).expect_err("eclipsed victim must fail the invariant");
    assert!(err.contains("eclipse"), "wrong failure: {err}");
}

// ---------------------------------------------------------------------------
// 21. City scale: 1,006 peers, sustained crash/restart churn, and a
//     regional outage on the timer-wheel DES core.
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "1,006-peer DES run needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_city_scale() {
    let sc = bank::city_scale();
    let (report, cluster) = scenario::run_cluster(&sc).expect("city-scale scenario");
    // Replay determinism with repair-phase jitter enabled: jitter is a
    // pure function of PeerId, so a second run from the same seed must
    // reproduce the identical report byte for byte.
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "city-scale scenario not deterministic");

    // Shape: ≥ 1,000 peers spread over all six regions.
    assert!(report.peers >= 1000, "only {} peers", report.peers);
    assert_eq!(report.peers, bank::CITY_INITIAL + 6 * bank::CITY_WAVE);
    let regions: BTreeSet<_> = (0..cluster.len()).map(|i| cluster.region_of(i)).collect();
    assert_eq!(regions.len(), 6, "only {} regions", regions.len());
    assert_eq!(report.contributions, 7);
    assert_eq!(report.checkpoints, 1);

    // The churn and the outage really produced tombstones, and the
    // digest-excluded queue telemetry recorded the load: the peak
    // backlog must at least cover one pending timer per live node.
    assert!(report.stats.dead_events > 0, "churn produced no dead events");
    assert!(
        report.stats.peak_queue_len >= report.peers as u64,
        "peak queue {} below one event per peer",
        report.stats.peak_queue_len
    );
    println!(
        "city-scale: peers={} events={} dead={} peak_queue={} end={}",
        report.peers,
        report.stats.events_processed,
        report.stats.dead_events,
        report.stats.peak_queue_len,
        report.end
    );

    // The flood half of the dissemination before/after: CI uploads this
    // next to the mesh variant's artifact.
    write_pubsub_artifact(&report, &cluster);
}

// ---------------------------------------------------------------------------
// 22/23. Gossip-mesh broadcast pair: 501 peers under thirty crash/restart
//        cycles. The mesh run must deliver every announcement to every
//        non-churned subscriber (the quiesce invariant) while paying an
//        integer factor less redundancy than the flood control on the
//        identical schedule.
// ---------------------------------------------------------------------------

/// Duplicates per useful delivery — the wasted `Publish` frames each
/// subscriber's copy costs the network (`benches/sim_scale.rs` records
/// the same quotient as `pubsub_redundancy`).
fn pubsub_redundancy(cluster: &peersdb::sim::des::Cluster<peersdb::peersdb::Node>) -> f64 {
    use peersdb::sim::harness;
    let (_published, _forwarded, delivered, duplicates) = harness::pubsub_totals(cluster);
    duplicates as f64 / delivered.max(1) as f64
}

/// Per-scenario pubsub-counter artifact (`PUBSUB_<scenario>.json`) CI
/// uploads alongside `BENCH_sim.json`: the cluster-wide dissemination
/// counters, the redundancy quotient, and the run's behavioral checksum,
/// so the dissemination trajectory is diffable per scenario across
/// versions without re-parsing the bench rollup.
fn write_pubsub_artifact(
    report: &scenario::ScenarioReport,
    cluster: &peersdb::sim::des::Cluster<peersdb::peersdb::Node>,
) {
    use peersdb::codec::Json;
    use peersdb::sim::harness;
    let (published, forwarded, delivered, duplicates) = harness::pubsub_totals(cluster);
    let (ihave_sent, iwant_served, grafts, prunes) = harness::pubsub_mesh_totals(cluster);
    let doc = Json::obj()
        .set("scenario", report.name)
        .set("peers", report.peers)
        .set("pubsub_published", published)
        .set("pubsub_forwarded", forwarded)
        .set("pubsub_delivered", delivered)
        .set("pubsub_duplicates", duplicates)
        .set("pubsub_redundancy", duplicates as f64 / delivered.max(1) as f64)
        .set("ihave_sent", ihave_sent)
        .set("iwant_served", iwant_served)
        .set("grafts", grafts)
        .set("prunes", prunes)
        .set("stats_checksum", format!("{:016x}", report.stats.checksum()));
    let path = format!("PUBSUB_{}.json", report.name);
    std::fs::write(&path, doc.pretty()).expect("write pubsub artifact");
    println!("wrote {path}");
}

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "501-peer broadcast pair needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_mesh_broadcast_delivers_with_bounded_redundancy() {
    use peersdb::sim::harness;

    let mesh_sc = bank::mesh_broadcast_churn();
    let (mesh_report, mesh_cluster) =
        scenario::run_cluster(&mesh_sc).expect("mesh broadcast scenario");
    // Replay determinism of the full mesh protocol — heartbeats, grafts,
    // lazy IHAVE batches, and IWANT pulls included in the digest.
    let replay = scenario::run(&mesh_sc).expect("replay");
    assert_eq!(mesh_report, replay, "mesh-broadcast-churn not deterministic");

    assert_eq!(mesh_report.peers, bank::BROADCAST_INITIAL + 2 * bank::BROADCAST_WAVE);
    assert_eq!(mesh_report.contributions, 5);
    assert_eq!(mesh_report.checkpoints, 1);

    // The mesh actually engaged: grafts formed it, heartbeats advertised
    // lazily, and at least one gap was healed by an IWANT pull — the
    // redundancy number below is earned by the protocol, not by a run
    // that silently stayed in flood mode.
    let mesh_totals = harness::pubsub_mesh_totals(&mesh_cluster);
    let (ihave_sent, iwant_served, grafts, _prunes) = mesh_totals;
    assert!(grafts > 0, "no mesh links were ever grafted");
    assert!(ihave_sent > 0, "heartbeats never advertised lazily");
    assert!(iwant_served > 0, "no delivery was ever completed by an IWANT pull");
    // The report's telemetry is exactly the cluster's engine totals (the
    // identity the quorum and transfer counter groups also pin).
    assert_eq!(
        mesh_totals,
        (
            mesh_report.stats.ihave_sent,
            mesh_report.stats.iwant_served,
            mesh_report.stats.grafts,
            mesh_report.stats.prunes,
        ),
        "report stats diverged from the cluster's engine totals"
    );

    // Full delivery under churn: the quiesce invariant already gated the
    // run on this; assert the predicate directly too so the test fails
    // loudly if the invariant is ever detached from the bank schedule.
    let pd = mesh_sc.invariants.pubsub_delivery.as_ref().expect("bank lost the invariant");
    scenario::check_pubsub_delivery(&mesh_cluster, pd).expect("mesh full delivery");

    // The flood control: identical schedule, knob off. It also delivers
    // fully (same invariant) — what it cannot do is bound the duplicate
    // factor.
    let flood_sc = bank::flood_broadcast_churn();
    let (flood_report, flood_cluster) =
        scenario::run_cluster(&flood_sc).expect("flood broadcast control");
    assert_eq!(flood_report.peers, mesh_report.peers);
    assert_eq!(flood_report.contributions, 5);
    assert_eq!(
        harness::pubsub_mesh_totals(&flood_cluster),
        (0, 0, 0, 0),
        "flood control produced mesh telemetry"
    );

    // Both modes delivered the five announcements to (at least) every
    // non-exempt subscriber: 471 eligible nodes × 5 messages, minus the
    // publisher's own five.
    let eligible = bank::BROADCAST_INITIAL + 2 * bank::BROADCAST_WAVE
        - bank::broadcast_churn_targets().len();
    let floor = (eligible as u64 - 1) * 5;
    let (_, _, mesh_delivered, _) = harness::pubsub_totals(&mesh_cluster);
    let (_, _, flood_delivered, _) = harness::pubsub_totals(&flood_cluster);
    assert!(mesh_delivered >= floor, "mesh delivered {mesh_delivered} < floor {floor}");
    assert!(flood_delivered >= floor, "flood delivered {flood_delivered} < floor {floor}");

    // The headline: duplicates per useful delivery collapses by at least
    // the factor `benches/sim_scale.rs` enforces on both pubsub pairs.
    let mesh_red = pubsub_redundancy(&mesh_cluster);
    let flood_red = pubsub_redundancy(&flood_cluster);
    println!(
        "broadcast redundancy: flood {flood_red:.2} -> mesh {mesh_red:.2} \
         ({:.1}x reduction; mesh ihave={ihave_sent} iwant_served={iwant_served} grafts={grafts})",
        flood_red / mesh_red.max(1e-9)
    );
    assert!(
        mesh_red * 2.0 <= flood_red,
        "mesh redundancy {mesh_red:.2} not >= 2x below flood {flood_red:.2}"
    );

    write_pubsub_artifact(&mesh_report, &mesh_cluster);
    write_pubsub_artifact(&flood_report, &flood_cluster);
}

// ---------------------------------------------------------------------------
// 24. City-scale churn with the mesh on: city_scale's schedule verbatim
//     under mesh dissemination. Named `scenario_city_scale_*` so the CI
//     city-scale job's test filter runs it next to the flood row.
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "1,006-peer DES run needs the release profile; CI runs `cargo test --release`"
)]
fn scenario_city_scale_mesh() {
    use peersdb::sim::harness;

    let sc = bank::city_scale_mesh();
    let (report, cluster) = scenario::run_cluster(&sc).expect("city-scale-mesh scenario");
    // Replay determinism with jitter AND the mesh enabled — the first
    // pin of the two interacting.
    let replay = scenario::run(&sc).expect("replay");
    assert_eq!(report, replay, "city-scale-mesh scenario not deterministic");

    // Same shape as the flood row: the schedule is shared verbatim.
    assert_eq!(report.peers, bank::CITY_INITIAL + 6 * bank::CITY_WAVE);
    assert_eq!(report.contributions, 7);
    assert_eq!(report.checkpoints, 1);
    assert!(report.stats.dead_events > 0, "churn produced no dead events");

    // The mesh engaged at city scale, through the regional outage.
    let (ihave_sent, iwant_served, grafts, prunes) = harness::pubsub_mesh_totals(&cluster);
    assert!(grafts > 0, "no mesh links were ever grafted");
    assert!(ihave_sent > 0, "heartbeats never advertised lazily");
    // Bounded redundancy without a paired flood run in-process: each
    // duplicate is a frame from another mesh member (or a crossed IWANT
    // serve), so duplicates per delivery must sit at or below the high
    // watermark — flood's sits near its fan-in, several times higher.
    // (The enforced cross-row ratio lives in `benches/sim_scale.rs` and
    // the broadcast-pair test, which run both modes.)
    let high = sc.cfg.mesh.as_ref().expect("mesh knob on").degree_high as f64;
    let red = pubsub_redundancy(&cluster);
    assert!(
        red <= high,
        "city-scale mesh redundancy {red:.2} above the high watermark {high}"
    );
    println!(
        "city-scale-mesh: peers={} events={} redundancy={red:.2} \
         ihave={ihave_sent} iwant_served={iwant_served} grafts={grafts} prunes={prunes}",
        report.peers, report.stats.events_processed
    );

    write_pubsub_artifact(&report, &cluster);
}

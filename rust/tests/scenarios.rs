//! The named fault-scenario bank: every scenario in here runs through
//! the declarative harness in `peersdb::sim::scenario`, passes the full
//! set of cluster-wide invariants (log convergence, quorum safety, DHT
//! routing health, block availability), and — because each test goes
//! through [`scenario::run_replayed`] — is verified to be byte-identical
//! on replay: same seed, same `SimStats`, same digest.
//!
//! These are the reproducible versions of the conditions the paper's
//! evaluation (and the collaborative-optimization line of work it builds
//! on) cares about: shared performance data must survive partitions,
//! churn, regional failure, load spikes, and malicious contributors.

use peersdb::peersdb::NodeConfig;
use peersdb::sim::regions::Region;
use peersdb::sim::scenario::{self, Fault, Scenario};
use peersdb::stores::documents::Verdict;
use peersdb::util::time::Duration;
use peersdb::validation::CostModel;

// ---------------------------------------------------------------------------
// 1. Network partition during active contribution traffic
// ---------------------------------------------------------------------------

#[test]
fn scenario_partition_heals_and_converges() {
    let mut sc = Scenario::named("partition-heal", 101, 8);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    let sc = sc
        .at(0, Fault::Contribute { node: 1, workload: 0, rows: 40 })
        // Split the cluster down the middle, root on side A.
        .at(5, Fault::Partition { a: vec![0, 1, 2, 3], b: vec![4, 5, 6, 7] })
        // Both sides keep contributing while partitioned.
        .at(7, Fault::Contribute { node: 2, workload: 1, rows: 40 })
        .at(9, Fault::Contribute { node: 5, workload: 2, rows: 40 })
        .at(11, Fault::Contribute { node: 6, workload: 3, rows: 40 })
        // Mid-partition, safety invariants must still hold.
        .at(20, Fault::Checkpoint)
        .at(30, Fault::Heal)
        .at(35, Fault::Contribute { node: 7, workload: 4, rows: 40 });
    let report = scenario::run_replayed(&sc).expect("partition scenario");
    assert_eq!(report.contributions, 5);
    assert_eq!(report.checkpoints, 1);
    // The partition actually dropped traffic — the fault was real.
    assert!(report.stats.msgs_dropped_blocked > 0, "partition never bit");
}

// ---------------------------------------------------------------------------
// 2. Regional outage and recovery
// ---------------------------------------------------------------------------

#[test]
fn scenario_regional_outage_recovers() {
    // 10 peers rotated across the 6 GCP regions: EuropeWest3 hosts
    // peers 1 and 7 (i % 6 == 1).
    let mut sc = Scenario::named("regional-outage", 202, 10);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    let sc = sc
        .at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(5, Fault::Outage { region: Region::EuropeWest3 })
        // The rest of the world keeps publishing during the outage.
        .at(8, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(12, Fault::Contribute { node: 4, workload: 2, rows: 30 })
        .at(20, Fault::Checkpoint)
        .at(40, Fault::Recover { region: Region::EuropeWest3 })
        .at(45, Fault::Contribute { node: 7, workload: 3, rows: 30 });
    let report = scenario::run_replayed(&sc).expect("regional outage scenario");
    assert_eq!(report.contributions, 4);
    // Offline peers drop deliveries; the outage was observable.
    assert!(report.stats.msgs_dropped_offline > 0, "outage never bit");
}

// ---------------------------------------------------------------------------
// 3. Crash/restart churn while data flows
// ---------------------------------------------------------------------------

#[test]
fn scenario_crash_restart_churn() {
    let mut sc = Scenario::named("crash-churn", 303, 8);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    let sc = sc
        .at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(2, Fault::Crash { node: 3 })
        .at(4, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        .at(8, Fault::Crash { node: 5 })
        .at(10, Fault::Contribute { node: 6, workload: 2, rows: 30 })
        .at(14, Fault::Restart { node: 3 })
        .at(16, Fault::Contribute { node: 3, workload: 3, rows: 30 })
        .at(20, Fault::Crash { node: 1 })
        .at(25, Fault::Restart { node: 5 })
        .at(30, Fault::Checkpoint)
        .at(35, Fault::Restart { node: 1 })
        .at(40, Fault::Contribute { node: 7, workload: 4, rows: 30 });
    let report = scenario::run_replayed(&sc).expect("churn scenario");
    assert_eq!(report.contributions, 5);
    assert_eq!(report.checkpoints, 1);
}

// ---------------------------------------------------------------------------
// 4. Flash-crowd join: the cluster doubles mid-run
// ---------------------------------------------------------------------------

#[test]
fn scenario_flash_crowd_syncs_history() {
    let mut sc = Scenario::named("flash-crowd", 404, 5);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    let sc = sc
        .at(0, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(3, Fault::Contribute { node: 2, workload: 1, rows: 30 })
        // Five newcomers join through the root at the same instant.
        .at(10, Fault::FlashCrowd { n: 5, region: Region::UsWest1 })
        // Traffic continues while they bootstrap.
        .at(12, Fault::Contribute { node: 3, workload: 2, rows: 30 })
        .at(30, Fault::Checkpoint);
    let report = scenario::run_replayed(&sc).expect("flash crowd scenario");
    assert_eq!(report.peers, 10, "joiners must be cluster members");
    assert_eq!(report.contributions, 3);
    // Convergence at quiesce (checked by the harness) implies the
    // joiners replicated history contributed *before* they existed.
}

// ---------------------------------------------------------------------------
// 5. Root-peer CPU strain (the paper's §IV-A artifact, injected)
// ---------------------------------------------------------------------------

#[test]
fn scenario_root_cpu_strain_inflates_but_converges() {
    let base = |name, seed| {
        let mut sc = Scenario::named(name, seed, 8);
        sc.quiesce = Duration::from_secs(600);
        sc.quiesce_poll = Duration::from_secs(5);
        sc.at(0, Fault::Contribute { node: 1, workload: 0, rows: 60 })
            .at(4, Fault::Contribute { node: 4, workload: 1, rows: 60 })
            .at(8, Fault::Contribute { node: 6, workload: 2, rows: 60 })
            .at(60, Fault::CpuRelief { node: 0 })
    };
    // Baseline vs the same schedule under a 5000× root CPU slowdown
    // (≈150 ms per message at the root, serialized — the paper's
    // root-peer strain artifact, exaggerated until unmistakable).
    let (nominal, ncluster) =
        scenario::run_cluster(&base("cpu-nominal", 505)).expect("nominal");
    let (strained, scluster) = scenario::run_cluster(
        &base("cpu-strain", 505).at_ms(0, Fault::CpuStrain { node: 0, factor: 5000 }),
    )
    .expect("strained");
    assert_eq!(nominal.contributions, strained.contributions);
    // The strained root replicates each file much later: every message
    // it processes costs 5000× and queues behind the rest.
    let repl_mean = |c: &peersdb::sim::Cluster<peersdb::peersdb::Node>| {
        c.node(0)
            .metrics
            .summary("replication_ms")
            .map(|s| s.mean())
            .unwrap_or(0.0)
    };
    let (m_nom, m_str) = (repl_mean(&ncluster), repl_mean(&scluster));
    assert!(m_nom > 0.0, "root never replicated in the baseline");
    assert!(
        m_str > m_nom * 1.5,
        "root replication under strain ({m_str:.0} ms) not slower than nominal ({m_nom:.0} ms)"
    );
    // Replay determinism for the strained schedule.
    let replay = scenario::run(
        &base("cpu-strain", 505).at_ms(0, Fault::CpuStrain { node: 0, factor: 5000 }),
    )
    .expect("replay");
    assert_eq!(strained, replay, "cpu-strain scenario not deterministic");
}

// ---------------------------------------------------------------------------
// 6. Byzantine validator: a lying minority cannot poison verdicts
// ---------------------------------------------------------------------------

#[test]
fn scenario_byzantine_minority_cannot_poison_quorum() {
    let mut sc = Scenario::named("byzantine-minority", 606, 8);
    sc.quiesce = Duration::from_secs(400);
    sc.stats_validators = true;
    sc.byzantine = vec![3];
    sc.cfg = NodeConfig {
        auto_validate: true,
        cost_model: CostModel::Linear { base_ns: 2_000_000, ns_per_kb: 50_000.0 },
        ..NodeConfig::default()
    };
    // With a verdict floor of 2 on timeout tallies and >50% agreement, a
    // single liar can never push a wrong verdict through a vote.
    sc.cfg.quorum.min_force_verdicts = 2;
    let sc = sc
        .at(0, Fault::Contribute { node: 1, workload: 0, rows: 60 })
        .at(5, Fault::Contribute { node: 2, workload: 1, rows: 60 })
        .at(10, Fault::ContributeCorrupt { node: 3, workload: 2, rows: 60, frac: 0.9 })
        .at(15, Fault::Contribute { node: 5, workload: 3, rows: 60 })
        .at(20, Fault::ContributeCorrupt { node: 6, workload: 4, rows: 60, frac: 0.9 });

    let (report, cluster) = scenario::run_cluster(&sc).expect("byzantine scenario");
    // Replay determinism (run_cluster doesn't go through run_replayed).
    let report2 = scenario::run(&sc).expect("replay");
    assert_eq!(report, report2, "byzantine scenario not deterministic");

    // No honest node may hold a wrong verdict, and each file must have
    // been judged (correctly) by several honest peers.
    for (cid, corrupt) in &report.cids {
        let expect = if *corrupt { Verdict::Invalid } else { Verdict::Valid };
        let wrong = if *corrupt { Verdict::Valid } else { Verdict::Invalid };
        let mut correct = 0;
        for i in 0..cluster.len() {
            if sc.byzantine.contains(&i) {
                continue;
            }
            match cluster.node(i).validations.verdict(cid) {
                Some(v) if v == wrong => {
                    panic!("honest node {i} adopted the byzantine verdict for {cid:?}")
                }
                Some(v) if v == expect => correct += 1,
                _ => {}
            }
        }
        assert!(correct >= 3, "only {correct} honest nodes judged {cid:?} (corrupt={corrupt})");
    }
}

// ---------------------------------------------------------------------------
// 7. Kitchen sink: loss spike + flapping links + churn, one schedule
// ---------------------------------------------------------------------------

#[test]
fn scenario_kitchen_sink_survives_everything() {
    let mut sc = Scenario::named("kitchen-sink", 707, 9);
    sc.quiesce = Duration::from_secs(600);
    sc.quiesce_poll = Duration::from_secs(5);
    let sc = sc
        .at(0, Fault::SetLoss { loss: 0.05 })
        .at(1, Fault::Contribute { node: 1, workload: 0, rows: 30 })
        .at(3, Fault::BlockPair { a: 2, b: 5 })
        .at(5, Fault::Contribute { node: 5, workload: 1, rows: 30 })
        .at(7, Fault::Crash { node: 4 })
        .at(9, Fault::Contribute { node: 6, workload: 2, rows: 30 })
        .at(11, Fault::UnblockPair { a: 2, b: 5 })
        .at(13, Fault::BlockPair { a: 1, b: 8 })
        .at(15, Fault::Restart { node: 4 })
        .at(18, Fault::Contribute { node: 8, workload: 3, rows: 30 })
        .at(25, Fault::Checkpoint);
    let report = scenario::run_replayed(&sc).expect("kitchen sink scenario");
    assert_eq!(report.contributions, 4);
    assert!(report.stats.msgs_dropped_loss > 0, "loss spike never bit");
}

//! End-to-end runtime tests: AOT artifacts → PJRT → train/predict/score.
//!
//! Requires `make artifacts` (skips gracefully when missing so plain
//! `cargo test` works before the first build) and the `pjrt` feature
//! (PJRT via the external `xla` crate, absent from the offline crate
//! set) — the whole file is compiled out otherwise.

#![cfg(feature = "pjrt")]

use peersdb::modeling::datagen::{generate_contribution, parse_contribution};
use peersdb::modeling::features::{encode_batch, DIM};
use peersdb::runtime::batching::padded_batches;
use peersdb::runtime::PerfModel;
use peersdb::util::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn load_train_predict_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = PerfModel::load(&dir).expect("load artifacts");
    assert_eq!(model.meta.features, DIM);
    assert!(model.param_count() > 4000, "MLP should have >4k params");

    // Build a training set from synthetic contributions — the same
    // parser/encoder path the collaborative workflow uses.
    let mut rng = Rng::new(42);
    let mut rows = Vec::new();
    for wl in 0..6 {
        let (file, _) = generate_contribution(&mut rng, wl, 200);
        rows.extend(parse_contribution(&file).unwrap());
    }
    let (xs, ys) = encode_batch(&rows);
    let batches = padded_batches(&xs, &ys, DIM, model.meta.batch);
    assert!(batches.len() >= 4);

    // Train a few epochs; loss must drop substantially.
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for epoch in 0..30 {
        let mut epoch_loss = 0.0;
        for (bx, by, bm) in &batches {
            epoch_loss += model.train_step(bx, by, bm, 0.05).expect("train step");
        }
        epoch_loss /= batches.len() as f32;
        if epoch == 0 {
            first = epoch_loss;
        }
        last = epoch_loss;
    }
    assert!(
        last < first * 0.25,
        "loss did not converge: {first} -> {last}"
    );

    // Predictions should correlate with targets (log-space MAE sanity).
    let (bx, by, bm) = &batches[0];
    let preds = model.predict(bx).expect("predict");
    let mut mae = 0.0;
    let mut n = 0.0;
    for i in 0..model.meta.batch {
        if bm[i] > 0.0 {
            mae += (preds[i] - by[i]).abs();
            n += 1.0;
        }
    }
    mae /= n;
    assert!(mae < 0.5, "log-space MAE too high: {mae}");
}

#[test]
fn knn_scores_separate_outliers() {
    let Some(dir) = artifacts_dir() else { return };
    let model = PerfModel::load(&dir).expect("load artifacts");
    let b = model.meta.batch;
    let r = model.meta.refset;
    let d = model.meta.features;
    let mut rng = Rng::new(7);
    // Reference set: plausible feature rows.
    let mut refs = vec![0f32; r * d];
    for v in refs.iter_mut() {
        *v = rng.f64_range(0.0, 1.0) as f32;
    }
    // Queries: first half inliers, second half far outliers.
    let mut xs = vec![0f32; b * d];
    for i in 0..b {
        for j in 0..d {
            xs[i * d + j] = if i < b / 2 {
                rng.f64_range(0.0, 1.0) as f32
            } else {
                rng.f64_range(20.0, 30.0) as f32
            };
        }
    }
    let scores = model.knn_score(&xs, &refs).expect("knn");
    let inlier: f32 = scores[..b / 2].iter().sum::<f32>() / (b / 2) as f32;
    let outlier: f32 = scores[b / 2..].iter().sum::<f32>() / (b / 2) as f32;
    assert!(
        outlier > inlier * 50.0,
        "outliers not separated: {inlier} vs {outlier}"
    );
}

#[test]
fn reset_restores_deterministic_init() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = PerfModel::load(&dir).expect("load");
    let before = model.export_params().unwrap();
    // Train a bit, then reset.
    let xs = vec![0.5f32; model.meta.batch * model.meta.features];
    let ys = vec![1.0f32; model.meta.batch];
    let mask = vec![1.0f32; model.meta.batch];
    model.train_step(&xs, &ys, &mask, 0.1).unwrap();
    let trained = model.export_params().unwrap();
    assert_ne!(before, trained, "training must change params");
    model.reset().unwrap();
    assert_eq!(before, model.export_params().unwrap());
}

#[test]
fn shape_mismatches_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = PerfModel::load(&dir).expect("load");
    assert!(model.train_step(&[0.0; 8], &[0.0; 1], &[0.0; 1], 0.1).is_err());
    assert!(model.predict(&[0.0; 7]).is_err());
    assert!(model.knn_score(&vec![0.0; 256 * 8], &[0.0; 3]).is_err());
}

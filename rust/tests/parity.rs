//! Sim-to-real parity: the bank's parity-tagged fault schedules run
//! twice — once in the DES, once against a real multi-threaded loopback
//! TCP cluster — and the two timing-free `ConvergenceReport`s must be
//! equal (`peersdb::sim::parity::differential`). Partitions lower to
//! per-direction frame-drop rules, slow links to per-frame pacing,
//! crashes to real thread stop/spawn, flash crowds to fresh node
//! spawns; sim-only faults fail the lowering with an explicit
//! `Unsupported` error (unit-tested in `sim::parity`), never a silent
//! skip.
//!
//! On a divergence, `differential` writes the two reports to
//! `PARITY_<scenario>_{sim,real}.json` in the test's working directory;
//! the CI parity job uploads them as the failure artifact.
//!
//! The real halves spawn ~4 OS threads per peer and sleep through the
//! schedule in wall-clock time, so each test runs tens of seconds and
//! is release-gated like the big DES runs.

use peersdb::sim::parity::{self, ConvergenceReport};
use peersdb::sim::{bank, Scenario};
use peersdb::stores::documents::Verdict;

/// The quick schedule-shape assertions every differential test makes
/// before trusting report equality: the run actually converged and every
/// contribution reached every expected holder.
fn assert_converged(sc: &Scenario, report: &ConvergenceReport, holders: usize) {
    assert_eq!(report.scenario, sc.name);
    assert!(report.logs_converged, "{}: logs did not converge", sc.name);
    assert!(
        report.peers.iter().all(|p| p.bootstrapped),
        "{}: a peer never bootstrapped",
        sc.name
    );
    for (k, &count) in report.provider_counts.iter().enumerate() {
        assert_eq!(
            count, holders,
            "{}: contribution {k} ended on {count} holders, expected {holders}",
            sc.name
        );
    }
}

// ---------------------------------------------------------------------------
// Differential runs (DES vs real TCP), one per parity-tagged bank row
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "real-clock TCP cluster run needs the release profile; CI runs `cargo test --release`"
)]
fn parity_partition_heal_sim_matches_real() {
    let sc = bank::parity_partition();
    let report = parity::differential(&sc).expect("sim and real runs must agree");
    // 6 initial peers + 1 flash-crowd joiner, 4 contributions, all held
    // everywhere (auto-pin) once the partition heals.
    assert_eq!(report.peers.len(), 7);
    assert_eq!(report.data_cids.len(), 4);
    assert!(report.corrupt.iter().all(|c| !c));
    assert_converged(&sc, &report, 7);
}

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "real-clock TCP cluster run needs the release profile; CI runs `cargo test --release`"
)]
fn parity_gc_repair_sim_matches_real() {
    let sc = bank::parity_gc_repair();
    let report = parity::differential(&sc).expect("sim and real runs must agree");
    // 7 peers, 2 contributions, both authored (then dropped) by node 1:
    // repair must leave every survivor holding both files and the
    // dropper holding neither, in both worlds.
    assert_eq!(report.peers.len(), 7);
    assert_eq!(report.data_cids.len(), 2);
    assert_converged(&sc, &report, 6);
    assert!(
        report.peers[1].holds.iter().all(|h| !h),
        "the dropper resurrected its own data"
    );
    for (i, p) in report.peers.iter().enumerate() {
        if i != 1 {
            assert!(p.holds.iter().all(|h| *h), "peer {i} missing a repaired file");
        }
    }
}

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "real-clock TCP cluster run needs the release profile; CI runs `cargo test --release`"
)]
fn parity_quorum_sim_matches_real() {
    let sc = bank::parity_quorum();
    let report = parity::differential(&sc).expect("sim and real runs must agree");
    assert_eq!(report.peers.len(), 7);
    assert_eq!(report.data_cids.len(), 3);
    assert_eq!(report.corrupt, vec![false, true, false]);
    assert_converged(&sc, &report, 7);
    // Every honest non-author holds the ground-truth verdict; authors
    // never self-validate; the byzantine store is masked.
    let authors = [1usize, 2, 5];
    for (i, p) in report.peers.iter().enumerate() {
        for (k, v) in p.verdicts.iter().enumerate() {
            let expected = if i == 3 || authors[k] == i {
                None
            } else if report.corrupt[k] {
                Some(Verdict::Invalid)
            } else {
                Some(Verdict::Valid)
            };
            assert_eq!(*v, expected, "peer {i} verdict for contribution {k}");
        }
    }
}

// ---------------------------------------------------------------------------
// The cheap half: lowering and eligibility guards that need no cluster
// ---------------------------------------------------------------------------

#[test]
fn every_parity_row_lowers_and_sim_only_rows_do_not() {
    let mut tagged = 0;
    let mut rejected = 0;
    for sc in bank::all() {
        match parity::lower_schedule(&sc) {
            Ok(actions) => {
                assert_eq!(actions.len(), sc.events.len(), "{}: lowering dropped a fault", sc.name);
                if sc.parity {
                    tagged += 1;
                    parity::parity_eligible(&sc)
                        .unwrap_or_else(|e| panic!("{} tagged but ineligible: {e}", sc.name));
                }
            }
            Err(e) => {
                assert!(!sc.parity, "{}: tagged parity but not lowerable: {e}", sc.name);
                rejected += 1;
                // The rejection is explicit and self-explaining, not a
                // silent skip.
                assert!(!e.why.is_empty() && !e.fault.is_empty());
            }
        }
    }
    assert!(tagged >= 3, "expected ≥ 3 parity-tagged bank rows, found {tagged}");
    assert!(rejected >= 1, "expected at least one sim-only bank row to be rejected");
}

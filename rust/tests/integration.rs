//! End-to-end integration tests: full PeersDB nodes over the DES.
//!
//! These exercise the complete §III workflows — join/bootstrap,
//! contribution, replication, collaborative validation, access control —
//! across multi-region simulated clusters.

use peersdb::blockstore::chunker::CHUNK_SIZE;
use peersdb::net::Outbox;
use peersdb::peersdb::{ChunkScheduler, Node, NodeConfig, NodeEvent, ValidationSource};
use peersdb::sim::harness::{assert_converged, build_cluster, contribute, drain_events, PeerSpec};
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::{Region, ALL};
use peersdb::stores::documents::Verdict;
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;
use peersdb::validation::{CostModel, StatsValidator};

fn default_specs(n: usize, cfg_fn: impl Fn(usize) -> NodeConfig) -> Vec<PeerSpec> {
    (0..n)
        .map(|i| PeerSpec {
            region: if i == 0 { Region::AsiaEast2 } else { ALL[i % ALL.len()] },
            start_at: Nanos(Duration::from_millis(200).0 * i as u64),
            cfg: cfg_fn(i),
            ..Default::default()
        })
        .collect()
}

#[test]
fn five_peer_cluster_bootstraps() {
    let specs = default_specs(5, |_| NodeConfig::default());
    let mut cluster = build_cluster(1, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(30));
    let events = drain_events(&mut cluster);
    let boots: Vec<usize> = events
        .iter()
        .filter(|(_, e)| matches!(e, NodeEvent::BootstrapDone { .. }))
        .map(|(i, _)| *i)
        .collect();
    // All four non-root peers complete bootstrap.
    assert_eq!(boots.len(), 4, "bootstrap events: {boots:?}");
    for i in 0..5 {
        assert!(cluster.node(i).is_bootstrapped(), "node {i}");
    }
}

#[test]
fn contribution_replicates_to_all_peers() {
    let specs = default_specs(6, |_| NodeConfig::default());
    let mut cluster = build_cluster(2, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));

    let mut rng = Rng::new(99);
    let (data, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, 0, 120);
    let root = contribute(&mut cluster, 2, &data, "spark-sort");
    cluster.run_for(Duration::from_secs(30));

    assert_converged(&mut cluster);
    // Every peer replicated the data file itself (auto-pin) and can read it.
    for i in 0..cluster.len() {
        let got = cluster.node(i).get_file(&root);
        assert_eq!(got.as_deref(), Some(&data[..]), "node {i} missing data");
    }
    let events = drain_events(&mut cluster);
    let repl = events
        .iter()
        .filter(|(_, e)| matches!(e, NodeEvent::ContributionReplicated { .. }))
        .count();
    assert_eq!(repl, 5, "5 remote peers replicate");
}

#[test]
fn multi_writer_concurrent_contributions_converge() {
    let specs = default_specs(8, |_| NodeConfig::default());
    let mut cluster = build_cluster(3, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));
    let mut rng = Rng::new(5);
    // Several peers contribute at the same instant (concurrent heads).
    for idx in [1usize, 3, 5, 7, 2] {
        let (data, _) =
            peersdb::modeling::datagen::generate_contribution(&mut rng, idx as u32 % 6, 60);
        contribute(&mut cluster, idx, &data, "spark-grep");
    }
    cluster.run_for(Duration::from_secs(40));
    assert_converged(&mut cluster);
    assert_eq!(cluster.node(0).contributions.len(), 5);
}

#[test]
fn late_joiner_syncs_full_history() {
    let mut specs = default_specs(4, |_| NodeConfig::default());
    // A fifth peer joins a minute later.
    specs.push(PeerSpec {
        region: Region::MeWest1,
        start_at: Nanos(Duration::from_secs(60).0),
        cfg: NodeConfig::default(),
        ..Default::default()
    });
    let mut cluster = build_cluster(4, NetModel::default(), specs);
    // Contribute before the late joiner starts.
    cluster.run_for(Duration::from_secs(8));
    let mut rng = Rng::new(7);
    for i in 0..3 {
        let (data, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, i, 40);
        contribute(&mut cluster, i as usize, &data, "flink-wordcount");
        cluster.run_for(Duration::from_secs(2));
    }
    cluster.run_for(Duration::from_secs(120));
    assert_converged(&mut cluster);
    let late = cluster.node(4);
    assert_eq!(late.contributions.len(), 3, "late joiner synced history");
    assert!(late.is_bootstrapped());
}

#[test]
fn wrong_passphrase_denied() {
    let mut specs = default_specs(2, |_| NodeConfig::default());
    specs[1].cfg.passphrase = "wrong-passphrase".into();
    let mut cluster = build_cluster(5, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(20));
    assert!(!cluster.node(1).is_bootstrapped());
    // The joiner retries its handshake; every attempt is rejected.
    assert!(cluster.node(0).metrics.counter("joins_rejected") >= 1);
    assert_eq!(cluster.node(0).metrics.counter("joins_accepted"), 0);
}

#[test]
fn private_data_never_served() {
    let specs = default_specs(3, |_| NodeConfig::default());
    let mut cluster = build_cluster(6, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));
    // Node 1 stores a private file.
    let secret = b"secret local monitoring data".to_vec();
    let cid = cluster.with_node(1, {
        let secret = secret.clone();
        move |n: &mut Node, _now, _out: &mut Outbox<_>| n.put_private(&secret)
    });
    // Node 2 learns the CID out of band and tries to fetch it.
    let owner = cluster.peer_id(1);
    cluster.with_node(2, move |n: &mut Node, now, out: &mut Outbox<_>| {
        n.fetch_cid(now, cid, vec![owner], out);
    });
    cluster.run_for(Duration::from_secs(30));
    // The owner denied it; the requester never obtained the data.
    assert!(cluster.node(2).get_file(&cid).is_none());
    assert_eq!(cluster.node(1).metrics.counter("private_denied"), 1);
    let events = drain_events(&mut cluster);
    assert!(events
        .iter()
        .any(|(i, e)| *i == 1 && matches!(e, NodeEvent::PrivateDenied { .. })));
}

#[test]
fn collaborative_validation_quorum_adopts_network_verdict() {
    // Root + 6 peers; validation on; validators are StatsValidator.
    let n = 7;
    let mk_cfg = || NodeConfig {
        auto_validate: true,
        cost_model: CostModel::Linear { base_ns: 2_000_000, ns_per_kb: 50_000.0 },
        ..NodeConfig::default()
    };
    let mut specs: Vec<PeerSpec> = (0..n)
        .map(|i| PeerSpec {
            region: ALL[i % ALL.len()],
            start_at: Nanos(Duration::from_millis(100).0 * i as u64),
            cfg: mk_cfg(),
            validator: Some(Box::new(StatsValidator::default())),
            ..Default::default()
        })
        .collect();
    // A late joiner arrives after the network has validated everything:
    // its quorum queries find stored verdicts and it adopts the network
    // decision instead of validating locally (§III-C).
    specs.push(PeerSpec {
        region: Region::EuropeWest3,
        start_at: Nanos(Duration::from_secs(150).0),
        cfg: mk_cfg(),
        validator: Some(Box::new(StatsValidator::default())),
        ..Default::default()
    });
    let mut cluster = build_cluster(7, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));

    let mut rng = Rng::new(11);
    let (good, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, 1, 80);
    let (bad, _) = peersdb::modeling::datagen::generate_corrupt_contribution(&mut rng, 1, 80, 0.9);
    let good_cid = contribute(&mut cluster, 1, &good, "spark-kmeans");
    cluster.run_for(Duration::from_secs(60));
    let bad_cid = contribute(&mut cluster, 2, &bad, "spark-kmeans");
    cluster.run_for(Duration::from_secs(240)); // includes the late joiner

    let events = drain_events(&mut cluster);
    let mut good_valid = 0;
    let mut bad_invalid = 0;
    let mut network_sourced = 0;
    for (_, e) in &events {
        if let NodeEvent::ValidationDone { data_cid, verdict, source, .. } = e {
            if *data_cid == good_cid && *verdict == Verdict::Valid {
                good_valid += 1;
            }
            if *data_cid == bad_cid && *verdict == Verdict::Invalid {
                bad_invalid += 1;
            }
            if *source == ValidationSource::Network {
                network_sourced += 1;
            }
        }
    }
    assert!(good_valid >= 5, "good contributions validated: {good_valid}");
    assert!(bad_invalid >= 5, "bad contributions flagged: {bad_invalid}");
    // Once early validators stored verdicts, later ones adopt them from
    // the network instead of re-validating.
    assert!(network_sourced >= 2, "network verdicts adopted: {network_sourced}");
}

#[test]
fn chunked_large_file_replicates() {
    let specs = default_specs(3, |_| NodeConfig::default());
    let mut cluster = build_cluster(8, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));
    let mut rng = Rng::new(13);
    let mut big = vec![0u8; CHUNK_SIZE * 2 + 100];
    rng.fill_bytes(&mut big);
    let root = contribute(&mut cluster, 1, &big, "spark-sort");
    cluster.run_for(Duration::from_secs(60));
    for i in 0..3 {
        assert_eq!(
            cluster.node(i).get_file(&root).as_deref(),
            Some(&big[..]),
            "node {i}"
        );
    }
}

#[test]
fn local_root_with_no_candidates_uses_one_provider_lookup_not_self_wants() {
    // Regression for the self-addressed-Want storm: a fetch that finds
    // the file's root block already local but arrives with no usable
    // candidate used to default its chunk source to *itself* — every
    // chunk was Want'ed from self, a guaranteed DontHave → Exhausted →
    // one doomed DHT lookup per chunk (chunk keys are never announced).
    // The fix runs exactly one provider lookup on the root key and
    // schedules chunks from whatever it finds.
    let specs = default_specs(3, |_| NodeConfig {
        auto_pin: false, // nobody replicates on their own
        ..NodeConfig::default()
    });
    let mut cluster = build_cluster(31, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));

    // Node 1 contributes a 3-block file (manifest + 2 chunks) and, per
    // the announce default, plants a provider record for the root.
    let mut rng = Rng::new(29);
    let mut big = vec![0u8; CHUNK_SIZE * 2 + 100];
    rng.fill_bytes(&mut big);
    let root = contribute(&mut cluster, 1, &big, "spark-sort");
    cluster.run_for(Duration::from_secs(5));

    // Hand node 2 the root block alone, then fetch with no candidates.
    let root_block = cluster.node(1).bs.get(&root).expect("author holds the root").to_vec();
    cluster.with_node(2, move |n: &mut Node, _now, _out: &mut Outbox<_>| {
        n.bs.put(peersdb::cid::Codec::Raw, root_block);
    });
    cluster.with_node(2, move |n: &mut Node, now, out: &mut Outbox<_>| {
        n.fetch_cid(now, root, vec![], out);
    });
    cluster.run_for(Duration::from_secs(30));

    // The lookup found the author's record and the chunks arrived.
    assert_eq!(
        cluster.node(2).get_file(&root).as_deref(),
        Some(&big[..]),
        "chunks never arrived"
    );
    let m = &cluster.node(2).metrics;
    assert_eq!(m.counter("chunk_provider_lookups"), 1, "exactly one root-key lookup");
    // The storm signature of the old bug: per-chunk self-Wants dying as
    // DontHave → Exhausted → empty per-chunk lookups. All absent.
    assert_eq!(m.counter("fetch_exhausted"), 0, "a chunk Want died");
    assert_eq!(m.counter("provider_lookup_empty"), 0, "a doomed chunk lookup ran");
    assert_eq!(m.counter("fetch_failed"), 0);
    // No fetch state leaks behind the completed file.
    assert_eq!(cluster.node(2).fetch_purposes_len(), 0);
    assert_eq!(cluster.node(2).bitswap_active_fetches(), 0);
    assert_eq!(cluster.node(2).bitswap_req_index_len(), 0);
}

#[test]
fn cancelled_file_fetch_cancels_live_siblings_and_leaks_nothing() {
    // Regression for the sibling-fetch leak: when one chunk exhausts
    // every provider and kills the whole file fetch, its still-live
    // sibling chunk fetches used to stay registered in the bitswap
    // engine (and their `fetch_purpose` entries leaked) until each
    // independently failed. The kill must now sweep them via
    // `bitswap::Engine::cancel`.
    //
    // Construction: node 2 holds only a 2-chunk file's root block and is
    // pointed at two providers that hold nothing at all. Striped
    // scheduling assigns one chunk to each; both DontHave, both chunks
    // get reassigned to the *other* provider, and whichever second
    // DontHave lands first exhausts its chunk's provider set while the
    // sibling's reassigned fetch is still in flight — exactly the state
    // the sweep exists for.
    let specs = default_specs(4, |_| NodeConfig {
        auto_pin: false,
        chunk_scheduler: ChunkScheduler::Quality,
        ..NodeConfig::default()
    });
    let mut cluster = build_cluster(32, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));

    // Build the file in a scratch store; only its root block enters the
    // cluster (content addressing keeps the CIDs identical).
    let mut rng = Rng::new(37);
    let mut big = vec![0u8; CHUNK_SIZE * 2 + 100];
    rng.fill_bytes(&mut big);
    let mut scratch = peersdb::blockstore::BlockStore::new();
    let added = peersdb::blockstore::chunker::add_file(&mut scratch, &big);
    let root = added.root;
    let root_block = scratch.get(&root).expect("scratch root").to_vec();

    let (p3, p0) = (cluster.peer_id(3), cluster.peer_id(0));
    cluster.with_node(2, move |n: &mut Node, now, out: &mut Outbox<_>| {
        n.bs.put(peersdb::cid::Codec::Raw, root_block);
        n.fetch_cid(now, root, vec![p3, p0], out);
    });
    cluster.run_for(Duration::from_secs(30));

    let m = &cluster.node(2).metrics;
    // Both chunks striped out, both bounced once to the other provider,
    // and the first chunk to exhaust both swept its live sibling.
    assert_eq!(m.counter("chunks_striped"), 2);
    assert_eq!(m.counter("transfer_reassignments"), 2);
    assert_eq!(m.counter("sibling_fetches_cancelled"), 1, "the live sibling was not swept");
    assert_eq!(m.counter("fetch_failed"), 1, "the file fetch must die exactly once");
    // The file is (correctly) absent, and so is every trace of the
    // fetch: no purpose entries, no engine fetches, no request index.
    assert!(cluster.node(2).get_file(&root).is_none());
    assert_eq!(cluster.node(2).fetch_purposes_len(), 0, "fetch_purpose leaked");
    assert_eq!(cluster.node(2).bitswap_active_fetches(), 0, "engine fetch leaked");
    assert_eq!(cluster.node(2).bitswap_req_index_len(), 0, "req_index leaked");
}

#[test]
fn restart_resyncs_via_anti_entropy() {
    let specs = default_specs(4, |_| NodeConfig::default());
    let mut cluster = build_cluster(9, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));
    // Take node 3 offline; contribute meanwhile.
    cluster.set_offline(3);
    let mut rng = Rng::new(17);
    let (data, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, 2, 50);
    contribute(&mut cluster, 1, &data, "spark-pagerank");
    cluster.run_for(Duration::from_secs(20));
    assert_eq!(cluster.node(3).contributions.len(), 0);
    // Node 3 returns: it rejoins (on_start) and syncs the missed entry.
    cluster.set_online(3);
    cluster.run_for(Duration::from_secs(60));
    assert_eq!(cluster.node(3).contributions.len(), 1, "missed entry recovered");
    assert_converged(&mut cluster);
}

#[test]
fn repair_replicates_without_auto_pin_and_announces_unconditionally() {
    // Auto-pinning off: the author is the only holder until the
    // availability-repair loop replicates. `announce_replicas` is also
    // off (the kubo-faithful default), which is the regression this
    // test pins down: repair-driven replicas must announce provider
    // records *anyway* — a repaired copy the DHT cannot discover does
    // not raise the provider count, so repair would re-trigger forever.
    let n = 5;
    let specs = default_specs(n, |_| NodeConfig {
        auto_pin: false,
        repair_interval: Duration::from_secs(5),
        replication_target: 3,
        ..NodeConfig::default()
    });
    let mut cluster = build_cluster(21, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));
    let mut rng = Rng::new(23);
    let (data, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, 0, 40);
    let root = contribute(&mut cluster, 1, &data, "spark-sort");
    cluster.run_for(Duration::from_secs(120));

    let key = peersdb::dht::Key::from_cid(&root);
    let holders: Vec<usize> = (0..n)
        .filter(|&i| peersdb::blockstore::chunker::has_file(&cluster.node(i).bs, &root))
        .collect();
    assert!(
        holders.len() >= 3,
        "repair never reached the replication target: holders {holders:?}"
    );
    assert!(holders.iter().any(|&i| i != 1), "no repair-driven replica exists");
    for &i in &holders {
        if i == 1 {
            continue; // the author announced at contribution time
        }
        // Every repair-driven holder self-recorded as provider when it
        // announced (provide() stores the local record immediately).
        assert!(
            cluster.node(i).dht.local_providers(&key).contains(&cluster.peer_id(i)),
            "repair-driven holder {i} never announced its replica"
        );
        assert!(cluster.node(i).metrics.counter("repair_refetches") > 0, "node {i}");
    }
}

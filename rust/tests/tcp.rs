//! The real-deployment end-to-end path as a test: the same loopback
//! TCP cluster + HTTP API flow that `examples/tcp_cluster.rs`
//! demonstrates, run quietly and asserted. Both entry points call
//! `peersdb::sim::parity::tcp_cluster_demo`, so the example can never
//! drift from what CI verifies.

#[test]
#[cfg_attr(
    debug_assertions,
    ignore = "real-clock TCP + HTTP round trip needs the release profile; CI runs `cargo test --release`"
)]
fn tcp_cluster_end_to_end() {
    peersdb::sim::parity::tcp_cluster_demo(false).expect("tcp_cluster flow");
}

//! Property-based tests over the coordinator's invariants: CRDT
//! convergence, routing correctness, codec roundtrips, batching/quorum
//! state machines, chunker integrity, and simulator determinism.

use peersdb::bitswap;
use peersdb::blockstore::{chunker, BlockStore, Pin};
use peersdb::cid::Cid;
use peersdb::codec::json::Json;
use peersdb::dht::kbucket::{KBucket, RoutingTable, K};
use peersdb::dht::{self, Key};
use peersdb::ipfs_log::Log;
use peersdb::net::{Outbox, PeerId, Runner};
use peersdb::peersdb::Message;
use peersdb::pubsub;
use peersdb::stores::documents::{ValidationRecord, Verdict};
use peersdb::testkit::{check, check_with_rng};
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;
use peersdb::validation::quorum::{QuorumConfig, VoteOutcome, VoteState};
use peersdb::validation::{BatchQueue, CostModel, Task};
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// CRDT log: convergence under arbitrary interleavings
// ---------------------------------------------------------------------------

/// A random multi-replica history: ops are appends at a replica or
/// partial syncs (replica pulls all entries from another).
#[derive(Debug, Clone)]
struct History {
    replicas: usize,
    ops: Vec<(usize, usize)>, // (op kind selector, replica/pair index)
}

#[test]
fn prop_log_replicas_converge() {
    check_with_rng(
        "log_replicas_converge",
        |r| History {
            replicas: r.range(2, 5),
            ops: (0..r.range(5, 40)).map(|_| (r.range(0, 100), r.range(0, 1000))).collect(),
        },
        |h, rng| {
            let authors: Vec<PeerId> = (0..h.replicas).map(|_| PeerId::from_rng(rng)).collect();
            let mut logs: Vec<Log> = (0..h.replicas).map(|_| Log::new()).collect();
            for (kind, arg) in &h.ops {
                let i = arg % h.replicas;
                if kind % 3 != 0 {
                    let payload = vec![(*kind % 256) as u8, (*arg % 256) as u8];
                    logs[i].append(authors[i], payload);
                } else {
                    let j = (arg / 7) % h.replicas;
                    if i != j {
                        let src = logs[j].clone();
                        logs[i].join(&src);
                    }
                }
            }
            // Full mesh sync twice → all converge.
            for _ in 0..2 {
                for i in 0..h.replicas {
                    for j in 0..h.replicas {
                        if i != j {
                            let src = logs[j].clone();
                            logs[i].join(&src);
                        }
                    }
                }
            }
            let d0 = logs[0].digest();
            for (i, l) in logs.iter().enumerate() {
                if l.digest() != d0 {
                    return Err(format!("replica {i} diverged"));
                }
                if l.heads() != logs[0].heads() {
                    return Err(format!("replica {i} heads differ"));
                }
                // Causality: parents precede children in traversal order.
                let mut seen = std::collections::HashSet::new();
                for (cid, e) in l.traverse() {
                    for p in &e.next {
                        if l.get(p).is_some() && !seen.contains(p) {
                            return Err("traversal violates causality".into());
                        }
                    }
                    seen.insert(cid);
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Kademlia: closest() agrees with brute force and is sorted
// ---------------------------------------------------------------------------

#[test]
fn prop_routing_table_closest_is_correct() {
    check_with_rng(
        "routing_table_closest",
        |r| (r.range(1, 200), r.range(1, 25)),
        |(n_peers, k), rng| {
            let own = Key(rng.bytes32());
            let mut rt = RoutingTable::new(own);
            let mut inserted = Vec::new();
            for _ in 0..*n_peers {
                let p = PeerId::from_rng(rng);
                rt.touch(p, Nanos(0));
                inserted.push(p);
            }
            let target = Key(rng.bytes32());
            let got = rt.closest(&target, *k);
            // Sorted by XOR distance.
            for w in got.windows(2) {
                if target.distance(&Key::from_peer(w[0])) > target.distance(&Key::from_peer(w[1])) {
                    return Err("closest() not sorted".into());
                }
            }
            // Agrees with brute force over *retained* peers.
            let mut brute = rt.peers();
            brute.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
            brute.truncate(*k);
            if got != brute {
                return Err("closest() != brute force".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// k-buckets: capacity, LRU eviction order, no self-insertion, placement
// ---------------------------------------------------------------------------

#[test]
fn prop_kbucket_lru_eviction_and_capacity() {
    check_with_rng(
        "kbucket_lru",
        |r| r.range(1, 120),
        |n_ops, rng| {
            let pool: Vec<PeerId> = (0..2 * K).map(|_| PeerId::from_rng(rng)).collect();
            let mut b = KBucket::default();
            let mut t = 0u64;
            for _ in 0..*n_ops {
                t += 1 + rng.gen_range(5); // strictly increasing → no LRU ties
                let p = pool[rng.range(0, pool.len())];
                if rng.chance(0.15) {
                    b.remove(&p);
                    if b.contains(&p) {
                        return Err("removed contact still present".into());
                    }
                    continue;
                }
                let evicting = b.len() == K && !b.contains(&p);
                let victim = if evicting { b.stalest() } else { None };
                b.touch(p, peersdb::util::time::Nanos(t));
                if !b.contains(&p) {
                    return Err("touched contact missing".into());
                }
                if b.len() > K {
                    return Err(format!("bucket over capacity: {}", b.len()));
                }
                if let Some(v) = victim {
                    if b.contains(&v) {
                        return Err("full bucket evicted someone other than the stalest".into());
                    }
                    if b.len() != K {
                        return Err("eviction changed the bucket size".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_routing_table_structural_invariants() {
    check_with_rng(
        "routing_table_structural",
        |r| (r.range(1, 300), r.range(0, 40)),
        |(touches, removes), rng| {
            let me = PeerId::from_rng(rng);
            let mut rt = RoutingTable::new(Key::from_peer(me));
            let mut known = vec![me]; // the own id is touched too — it must never stick
            for i in 0..*touches {
                let p = if rng.chance(0.3) {
                    known[rng.range(0, known.len())]
                } else {
                    let p = PeerId::from_rng(rng);
                    known.push(p);
                    p
                };
                rt.touch(p, Nanos(i as u64));
            }
            for _ in 0..*removes {
                rt.remove(&known[rng.range(0, known.len())]);
            }
            // Capacity, placement (each contact in the bucket its XOR
            // distance to `me` selects), uniqueness, no self-insertion.
            rt.check_invariants()?;
            if rt.contains(&me) {
                return Err("own id present in routing table".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// dht::lookup extraction guard: with a single path and no distance
// verification, the extracted state machine must be move-for-move
// identical to the algorithm the engine inlined before the refactor —
// same query batches in the same order, same termination, same results.
// The reference below is a line-for-line port of that legacy code; if
// the extraction drifted, random reply/timeout interleavings would
// diverge here long before a scenario checksum could.
// ---------------------------------------------------------------------------

use peersdb::dht::lookup::{Drive, LookupConfig, LookupKind, LookupState};
use std::collections::BTreeSet;

/// The pre-extraction single-path lookup, verbatim (shortlist keyed by
/// XOR distance, queried-on-send marks, α-parallel selection over the k
/// closest, provider early exit, timeout frees the in-flight slot).
struct LegacyLookup {
    target: Key,
    get_providers: bool,
    full: bool,
    alpha: usize,
    k: usize,
    providers_needed: usize,
    shortlist: BTreeMap<[u8; 32], (PeerId, bool)>,
    in_flight: usize,
    providers: BTreeSet<PeerId>,
    done: bool,
}

enum LegacyDrive {
    Done(Vec<PeerId>, Vec<PeerId>),
    Query(Vec<PeerId>),
    Wait,
}

impl LegacyLookup {
    fn insert(&mut self, peer: PeerId) {
        let d = self.target.distance(&Key::from_peer(peer)).0;
        self.shortlist.entry(d).or_insert((peer, false));
    }

    fn drive(&mut self) -> LegacyDrive {
        if self.done {
            return LegacyDrive::Wait;
        }
        let enough_providers = self.get_providers
            && !self.full
            && self.providers_needed > 0
            && self.providers.len() >= self.providers_needed;
        let k_closest_all_queried = self.shortlist.values().take(self.k).all(|(_, q)| *q);
        if enough_providers || (k_closest_all_queried && self.in_flight == 0) {
            self.done = true;
            let closest = self.shortlist.values().take(self.k).map(|(p, _)| *p).collect();
            let providers = self.providers.iter().copied().collect();
            return LegacyDrive::Done(closest, providers);
        }
        let mut to_query = Vec::new();
        let in_flight = self.in_flight;
        let alpha = self.alpha;
        for (_, (peer, queried)) in self.shortlist.iter_mut().take(self.k) {
            if in_flight + to_query.len() >= alpha {
                break;
            }
            if !*queried {
                *queried = true;
                to_query.push(*peer);
            }
        }
        self.in_flight += to_query.len();
        if to_query.is_empty() {
            LegacyDrive::Wait
        } else {
            LegacyDrive::Query(to_query)
        }
    }

    fn on_reply(&mut self, own: PeerId, from: PeerId, providers: &[PeerId], closer: &[PeerId]) {
        if self.done {
            return;
        }
        self.in_flight = self.in_flight.saturating_sub(1);
        let d = self.target.distance(&Key::from_peer(from)).0;
        if let Some(entry) = self.shortlist.get_mut(&d) {
            entry.1 = true;
        }
        for &p in closer {
            if p != own {
                self.insert(p);
            }
        }
        for &p in providers {
            self.providers.insert(p);
        }
    }

    fn on_timeout(&mut self) {
        if self.done {
            return;
        }
        self.in_flight = self.in_flight.saturating_sub(1);
    }
}

/// A random static "network" for driving lookups sans-io: every peer has
/// a fixed closer-list and provider-list it would reply with.
struct Topology {
    pool: Vec<PeerId>,
    closer: BTreeMap<PeerId, Vec<PeerId>>,
    providers: BTreeMap<PeerId, Vec<PeerId>>,
}

fn random_topology(rng: &mut Rng, n: usize) -> Topology {
    let pool: Vec<PeerId> = (0..n).map(|_| PeerId::from_rng(rng)).collect();
    let mut closer = BTreeMap::new();
    let mut providers = BTreeMap::new();
    for &p in &pool {
        let n_closer = rng.range(0, 7);
        let list: Vec<PeerId> = (0..n_closer).map(|_| pool[rng.range(0, pool.len())]).collect();
        let n_prov = rng.range(0, 3);
        let provs: Vec<PeerId> = (0..n_prov).map(|_| pool[rng.range(0, pool.len())]).collect();
        closer.insert(p, list);
        providers.insert(p, provs);
    }
    Topology { pool, closer, providers }
}

#[test]
fn prop_lookup_single_path_matches_legacy_reference() {
    check_with_rng(
        "lookup_single_path_matches_legacy",
        |r| {
            (
                r.range(4, 40),  // pool size
                r.range(0, 12),  // seed count
                r.range(1, 5),   // alpha
                r.range(2, 9),   // k
                r.range(0, 4),   // providers_needed
                r.range(0, 4),   // kind/full selector
            )
        },
        |(n, n_seeds, alpha, k, needed, kind_sel), rng| {
            let topo = random_topology(rng, *n);
            let own = PeerId::from_rng(rng);
            let target = Key(rng.bytes32());
            let seeds: Vec<PeerId> =
                (0..*n_seeds).map(|_| topo.pool[rng.range(0, topo.pool.len())]).collect();
            let (get_providers, full) = match kind_sel % 3 {
                0 => (false, false),
                1 => (true, false),
                _ => (true, true),
            };
            let mut legacy = LegacyLookup {
                target,
                get_providers,
                full,
                alpha: *alpha,
                k: *k,
                providers_needed: *needed,
                shortlist: BTreeMap::new(),
                in_flight: 0,
                providers: BTreeSet::new(),
                done: false,
            };
            for &s in &seeds {
                legacy.insert(s);
            }
            let kind = if get_providers { LookupKind::GetProviders } else { LookupKind::FindNode };
            let cfg = LookupConfig {
                alpha: *alpha,
                k: *k,
                providers_needed: *needed,
                paths: 1,
                verify_distance: false,
            };
            let mut extracted = LookupState::new(own, kind, target, full, cfg, seeds.clone());

            // Drive both in lockstep; every verdict must match.
            let mut outstanding: Vec<PeerId> = Vec::new();
            let mut done = false;
            let step = |legacy: &mut LegacyLookup,
                        extracted: &mut LookupState|
             -> Result<Option<Vec<PeerId>>, String> {
                match (legacy.drive(), extracted.drive(0)) {
                    (LegacyDrive::Query(a), Drive::Query(b)) => {
                        if a != b {
                            return Err(format!("query batches diverged: {a:?} vs {b:?}"));
                        }
                        Ok(Some(a))
                    }
                    (LegacyDrive::Wait, Drive::Wait) => Ok(Some(Vec::new())),
                    (LegacyDrive::Done(c, p), Drive::Done) => {
                        if (c, p) != extracted.result() {
                            return Err("terminal results diverged".into());
                        }
                        Ok(None)
                    }
                    _ => Err("drive verdicts diverged (Done/Query/Wait mismatch)".into()),
                }
            };
            match step(&mut legacy, &mut extracted)? {
                None => done = true,
                Some(q) => outstanding.extend(q),
            }
            let mut hops = 0;
            while !done {
                hops += 1;
                if hops > 10_000 {
                    return Err("lookup never terminated".into());
                }
                if outstanding.is_empty() {
                    return Err("stalled: not done but nothing outstanding".into());
                }
                let peer = outstanding.remove(rng.range(0, outstanding.len()));
                if rng.chance(0.75) {
                    let closer = topo.closer[&peer].clone();
                    let providers = topo.providers[&peer].clone();
                    legacy.on_reply(own, peer, &providers, &closer);
                    extracted.on_reply(0, peer, providers, &closer);
                } else {
                    legacy.on_timeout();
                    extracted.on_timeout(0);
                }
                match step(&mut legacy, &mut extracted)? {
                    None => done = true,
                    Some(q) => outstanding.extend(q),
                }
            }
            if !extracted.is_done() {
                return Err("extracted machine not done at termination".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Disjoint-path lookups: per-path queried sets are pairwise disjoint,
// and the merged result is exactly the union of the per-path results
// (k closest over the union of per-path closest sets; providers are the
// union of everything any path was told).
// ---------------------------------------------------------------------------

#[test]
fn prop_disjoint_paths_partition_queries_and_merge_results() {
    check_with_rng(
        "disjoint_paths_partition_queries",
        |r| (r.range(6, 40), r.range(2, 5), r.range(1, 4), r.range(2, 9)),
        |(n, d, alpha, k), rng| {
            let topo = random_topology(rng, *n);
            let own = PeerId::from_rng(rng);
            let target = Key(rng.bytes32());
            let mut seeds: Vec<PeerId> = topo.pool.clone();
            seeds.sort_by_key(|p| target.distance(&Key::from_peer(*p)));
            seeds.truncate(rng.range(1, topo.pool.len()));
            let cfg = LookupConfig {
                alpha: *alpha,
                k: *k,
                providers_needed: 0,
                paths: *d,
                verify_distance: false,
            };
            // Exhaustive provider lookup: no early exit, so every
            // delivered provider must surface in the merged result.
            let mut lk =
                LookupState::new(own, LookupKind::GetProviders, target, true, cfg, seeds);
            let mut outstanding: Vec<(usize, PeerId)> = Vec::new();
            let mut delivered_providers: BTreeSet<PeerId> = BTreeSet::new();
            for pi in 0..*d {
                if let Drive::Query(q) = lk.drive(pi) {
                    outstanding.extend(q.into_iter().map(|p| (pi, p)));
                }
            }
            let mut hops = 0;
            while !lk.is_done() {
                hops += 1;
                if hops > 10_000 {
                    return Err("lookup never terminated".into());
                }
                if outstanding.is_empty() {
                    return Err("stalled: not done but nothing outstanding".into());
                }
                let (pi, peer) = outstanding.remove(rng.range(0, outstanding.len()));
                if rng.chance(0.75) {
                    let closer = topo.closer[&peer].clone();
                    let providers = topo.providers[&peer].clone();
                    delivered_providers.extend(providers.iter().copied());
                    lk.on_reply(pi, peer, providers, &closer);
                } else {
                    lk.on_timeout(pi);
                }
                if let Drive::Query(q) = lk.drive(pi) {
                    outstanding.extend(q.into_iter().map(|p| (pi, p)));
                }
            }

            // 1. Pairwise-disjoint queried sets.
            for a in 0..*d {
                let qa: BTreeSet<PeerId> = lk.queried(a).into_iter().collect();
                for b in (a + 1)..*d {
                    if lk.queried(b).iter().any(|p| qa.contains(p)) {
                        return Err(format!("paths {a} and {b} queried a common peer"));
                    }
                }
            }

            // 2. Merged closest == k closest over the union of the
            //    per-path closest sets, in distance order, no duplicates.
            let (closest, providers) = lk.result();
            let mut union: BTreeMap<[u8; 32], PeerId> = BTreeMap::new();
            for pi in 0..*d {
                for p in lk.path_closest(pi) {
                    union.insert(target.distance(&Key::from_peer(p)).0, p);
                }
            }
            let expect: Vec<PeerId> = union.into_values().take(*k).collect();
            if closest != expect {
                return Err(format!(
                    "merged closest != union of per-path results: {closest:?} vs {expect:?}"
                ));
            }
            for w in closest.windows(2) {
                if target.distance(&Key::from_peer(w[0])) >= target.distance(&Key::from_peer(w[1]))
                {
                    return Err("merged closest not strictly distance-ordered".into());
                }
            }

            // 3. Providers == union of everything delivered on any path.
            let got: BTreeSet<PeerId> = providers.into_iter().collect();
            if got != delivered_providers {
                return Err(format!(
                    "provider union mismatch: {} merged vs {} delivered",
                    got.len(),
                    delivered_providers.len()
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Codec: roundtrips for random wire messages and JSON values
// ---------------------------------------------------------------------------

fn random_cid(rng: &mut Rng) -> Cid {
    Cid::of_raw(&rng.bytes32())
}

fn random_peers(rng: &mut Rng, max: usize) -> Vec<PeerId> {
    (0..rng.range(0, max)).map(|_| PeerId::from_rng(rng)).collect()
}

fn random_msg_ids(rng: &mut Rng, max: usize) -> Vec<pubsub::MsgId> {
    (0..rng.range(0, max))
        .map(|_| pubsub::MsgId { origin: PeerId::from_rng(rng), seq: rng.next_u64() })
        .collect()
}

/// Every `Message` variant (and, through the first three arms, every
/// dht/bitswap/pubsub sub-variant) with randomized field contents — the
/// generator behind both the roundtrip and the wire-size-exactness
/// properties, so a new message variant that misses either codec or
/// `WireSize` is caught here.
fn random_message(rng: &mut Rng) -> Message {
    let req_id = rng.next_u64() >> 1;
    match rng.range(0, 23) {
        0 => Message::Dht(dht::Rpc::Ping { req_id }),
        1 => Message::Dht(dht::Rpc::Pong { req_id }),
        2 => Message::Dht(dht::Rpc::FindNode { req_id, target: Key(rng.bytes32()) }),
        3 => Message::Dht(dht::Rpc::FindNodeReply { req_id, closer: random_peers(rng, 8) }),
        4 => Message::Dht(dht::Rpc::GetProviders { req_id, key: Key(rng.bytes32()) }),
        5 => Message::Dht(dht::Rpc::GetProvidersReply {
            req_id,
            providers: random_peers(rng, 5),
            closer: random_peers(rng, 5),
        }),
        6 => Message::Dht(dht::Rpc::AddProvider {
            key: Key(rng.bytes32()),
            provider: PeerId::from_rng(rng),
        }),
        18 => Message::Dht(dht::Rpc::RemoveProvider { key: Key(rng.bytes32()) }),
        7 => Message::Bitswap(bitswap::Msg::Want { req_id, cid: random_cid(rng) }),
        8 => Message::Bitswap(bitswap::Msg::Block {
            req_id,
            cid: random_cid(rng),
            data: {
                let mut v = vec![0u8; rng.range(0, 2000)];
                rng.fill_bytes(&mut v);
                v.into()
            },
        }),
        9 => Message::Bitswap(bitswap::Msg::DontHave { req_id, cid: random_cid(rng) }),
        10 => Message::Pubsub(pubsub::Msg::Subscriptions {
            topics: (0..rng.range(0, 6)).map(|_| pubsub::Topic(rng.next_u64())).collect(),
        }),
        11 => Message::Pubsub(pubsub::Msg::Publish {
            topic: pubsub::Topic(rng.next_u64()),
            origin: PeerId::from_rng(rng),
            seq: rng.next_u64() >> 1,
            hops: rng.range(0, 16) as u8,
            data: {
                let mut v = vec![0u8; rng.range(0, 200)];
                rng.fill_bytes(&mut v);
                v.into()
            },
        }),
        // The gossip-mesh control plane: `IHave`/`IWant` sizes must be
        // exactly computable from the id count alone (fixed-width seqs),
        // which is what the wire-size-exactness property pins here.
        19 => Message::Pubsub(pubsub::Msg::IHave {
            topic: pubsub::Topic(rng.next_u64()),
            ids: random_msg_ids(rng, 8),
        }),
        20 => Message::Pubsub(pubsub::Msg::IWant { ids: random_msg_ids(rng, 8) }),
        21 => Message::Pubsub(pubsub::Msg::Graft { topic: pubsub::Topic(rng.next_u64()) }),
        22 => Message::Pubsub(pubsub::Msg::Prune { topic: pubsub::Topic(rng.next_u64()) }),
        12 => Message::Join { passphrase: rng.bytes32() },
        13 => Message::JoinAck {
            accepted: rng.chance(0.5),
            peers: random_peers(rng, 8),
            heads: (0..rng.range(0, 8)).map(|_| random_cid(rng)).collect(),
        },
        14 => Message::HeadsRequest,
        15 => Message::HeadsReply {
            heads: (0..rng.range(0, 10)).map(|_| random_cid(rng)).collect(),
        },
        16 => Message::ValQuery { req_id, cid: random_cid(rng) },
        _ => Message::ValReply {
            req_id,
            cid: random_cid(rng),
            record: if rng.chance(0.5) {
                Some(ValidationRecord {
                    data_cid: random_cid(rng),
                    verdict: [Verdict::Valid, Verdict::Invalid, Verdict::Inconclusive]
                        [rng.range(0, 3)],
                    score: rng.f64(),
                    validator: PeerId::from_rng(rng),
                    validated_at: rng.next_u64() >> 1,
                    cost_ns: rng.next_u64() >> 1,
                })
            } else {
                None
            },
        },
    }
}

#[test]
fn prop_wire_messages_roundtrip() {
    check_with_rng(
        "wire_messages_roundtrip",
        |_| (),
        |_, rng| {
            let msg = random_message(rng);
            let bytes = peersdb::codec::to_bytes(&msg);
            let back: Message = peersdb::codec::from_bytes(&bytes)
                .map_err(|e| format!("decode failed: {e}"))?;
            if back != msg {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

/// The simulator's bandwidth model charges `wire_size()` bytes per send
/// without encoding anything, so the O(1) computation must equal the
/// encoded length *exactly* for every message shape — any drift after a
/// format change silently skews every bandwidth figure the reproduction
/// reports.
#[test]
fn prop_wire_size_is_exact() {
    check_with_rng(
        "wire_size_is_exact",
        |_| (),
        |_, rng| {
            let msg = random_message(rng);
            let exact = peersdb::codec::to_bytes(&msg).len();
            let computed = peersdb::net::WireSize::wire_size(&msg);
            if computed != exact {
                return Err(format!("wire_size {computed} != encoded {exact} for {msg:?}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Blob: codec roundtrip and zero-copy clone/store semantics
// ---------------------------------------------------------------------------

#[test]
fn prop_blob_codec_roundtrip_and_sharing() {
    use peersdb::util::Blob;

    check_with_rng(
        "blob_codec_roundtrip",
        |r| r.range(0, 4096),
        |size, rng| {
            let mut data = vec![0u8; *size];
            rng.fill_bytes(&mut data);
            let blob = Blob::from(data.clone());
            if blob != data {
                return Err("Blob construction changed contents".into());
            }
            // Codec roundtrip (one copy off the wire, then shared).
            let bytes = peersdb::codec::to_bytes(&blob);
            let back: Blob = peersdb::codec::from_bytes(&bytes)
                .map_err(|e| format!("decode failed: {e}"))?;
            if back != blob {
                return Err("roundtrip mismatch".into());
            }
            // Clones alias the same allocation (the zero-copy property).
            let clone = blob.clone();
            if !Blob::ptr_eq(&clone, &blob) {
                return Err("clone did not share the allocation".into());
            }
            // A blockstore round-trip through the verified-fetch path
            // must adopt the allocation rather than copy it.
            let mut bs = BlockStore::new();
            let cid = Cid::of_raw(&blob);
            bs.put_trusted(cid, blob.clone());
            let held = bs.get_blob(&cid).ok_or("stored blob missing")?;
            if !Blob::ptr_eq(&held, &blob) {
                return Err("blockstore copied the payload".into());
            }
            Ok(())
        },
    );
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth > 3 { rng.range(0, 4) } else { rng.range(0, 6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num((rng.next_u32() as f64) / 8.0 - 1000.0),
        3 => Json::Str(
            (0..rng.range(0, 12)).map(|_| ('a'..='z').nth(rng.range(0, 26)).unwrap()).collect(),
        ),
        4 => Json::Arr((0..rng.range(0, 5)).map(|_| random_json(rng, depth + 1)).collect()),
        _ => {
            let mut m = BTreeMap::new();
            for _ in 0..rng.range(0, 5) {
                let k: String = (0..rng.range(1, 8))
                    .map(|_| ('a'..='z').nth(rng.range(0, 26)).unwrap())
                    .collect();
                m.insert(k, random_json(rng, depth + 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    check_with_rng(
        "json_roundtrip",
        |_| (),
        |_, rng| {
            let v = random_json(rng, 0);
            let compact = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
            let pretty = Json::parse(&v.pretty()).map_err(|e| e.to_string())?;
            if compact != v || pretty != v {
                return Err("json roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Chunker: files of arbitrary size roundtrip and report integrity
// ---------------------------------------------------------------------------

#[test]
fn prop_chunker_roundtrip_and_has_file() {
    check_with_rng(
        "chunker_roundtrip",
        |r| r.range(0, 3 * chunker::CHUNK_SIZE + 17),
        |size, rng| {
            let mut bs = BlockStore::new();
            let mut data = vec![0u8; *size];
            rng.fill_bytes(&mut data);
            let res = chunker::add_file(&mut bs, &data);
            if !chunker::has_file(&bs, &res.root) {
                return Err("has_file false after add".into());
            }
            let back = chunker::get_file(&bs, &res.root).ok_or("get_file none")?;
            if back != data {
                return Err("content mismatch".into());
            }
            // Every listed block verifies against its CID.
            for b in &res.blocks {
                let blk = bs.get(b).ok_or("missing block")?;
                if !b.verifies(blk) {
                    return Err("block fails verification".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chunker_detects_any_missing_chunk() {
    check_with_rng(
        "chunker_detects_any_missing_chunk",
        |r| r.range(chunker::CHUNK_SIZE + 1, 4 * chunker::CHUNK_SIZE),
        |size, rng| {
            let mut bs = BlockStore::new();
            let mut data = vec![0u8; *size];
            rng.fill_bytes(&mut data);
            let res = chunker::add_file(&mut bs, &data);
            if res.blocks.len() < 3 {
                return Err("multi-chunk file expected".into());
            }
            // Drop one random chunk (never the manifest root) by pinning
            // everything else and collecting garbage.
            let drop_idx = 1 + rng.range(0, res.blocks.len() - 1);
            for (i, b) in res.blocks.iter().enumerate() {
                if i != drop_idx {
                    bs.pin(b, Pin::Local);
                }
            }
            bs.gc();
            if chunker::has_file(&bs, &res.root) {
                return Err("has_file despite a missing chunk".into());
            }
            if chunker::get_file(&bs, &res.root).is_some() {
                return Err("get_file reassembled a file with a hole".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Blockstore: pins are inviolable under arbitrary put/pin/unpin/gc
// interleavings (model-based — the mirror map implements the documented
// semantics and the store must never drift from it)
// ---------------------------------------------------------------------------

#[test]
fn prop_blockstore_gc_respects_pins_exactly() {
    use peersdb::cid::Codec;
    use std::collections::BTreeSet;

    check_with_rng(
        "blockstore_gc_pin_model",
        |r| r.range(1, 150),
        |n_ops, rng| {
            let mut bs = BlockStore::new();
            // Mirror model: cid → (payload length, pin class).
            let mut model: BTreeMap<Cid, (usize, Option<Pin>)> = BTreeMap::new();
            let mut known: Vec<Cid> = Vec::new();
            for _ in 0..*n_ops {
                match rng.range(0, 10) {
                    0..=3 => {
                        // Put: tiny payloads from a small alphabet, so
                        // deduplicating re-puts happen often.
                        let len = rng.range(1, 40);
                        let data = vec![rng.range(0, 4) as u8; len];
                        let cid = bs.put(Codec::Raw, data);
                        model.entry(cid).or_insert((len, None));
                        known.push(cid);
                    }
                    4..=6 => {
                        // `known` may reference blocks a gc collected:
                        // pinning those must report absence.
                        if known.is_empty() {
                            continue;
                        }
                        let cid = known[rng.range(0, known.len())];
                        let pin = if rng.chance(0.5) { Pin::Local } else { Pin::Replica };
                        let present = bs.pin(&cid, pin);
                        match model.get_mut(&cid) {
                            Some((_, p)) => {
                                if !present {
                                    return Err("pin() denied a present block".into());
                                }
                                // Local is the stronger class: never downgraded.
                                if *p != Some(Pin::Local) {
                                    *p = Some(pin);
                                }
                            }
                            None if present => {
                                return Err("pin() accepted a collected block".into());
                            }
                            None => {}
                        }
                    }
                    7 | 8 => {
                        if known.is_empty() {
                            continue;
                        }
                        let cid = known[rng.range(0, known.len())];
                        let was = bs.unpin(&cid);
                        match model.get_mut(&cid) {
                            Some((_, p)) => {
                                if was != p.is_some() {
                                    return Err("unpin() return drifted from model".into());
                                }
                                *p = None;
                            }
                            None if was => {
                                return Err("unpin() unpinned a collected block".into());
                            }
                            None => {}
                        }
                    }
                    _ => {
                        let unpinned: Vec<&(usize, Option<Pin>)> =
                            model.values().filter(|(_, p)| p.is_none()).collect();
                        let expect_blocks = unpinned.len();
                        let expect_bytes: usize = unpinned.iter().map(|(l, _)| *l).sum();
                        let (blocks, bytes) = bs.gc();
                        if (blocks, bytes) != (expect_blocks, expect_bytes) {
                            return Err(format!(
                                "gc returned ({blocks}, {bytes}), model says \
                                 ({expect_blocks}, {expect_bytes})"
                            ));
                        }
                        model.retain(|_, (_, p)| p.is_some());
                    }
                }
            }
            // Final sweep, then every property at once.
            bs.gc();
            model.retain(|_, (_, p)| p.is_some());
            for (cid, (_, pin)) in &model {
                if !bs.has(cid) {
                    return Err("gc collected a pinned block".into());
                }
                if bs.pin_of(cid) != *pin {
                    return Err("pin class drifted (Local downgraded?)".into());
                }
            }
            // After a gc, the surviving key set IS the pinned set.
            let surviving: BTreeSet<Cid> = model.keys().copied().collect();
            if bs.pinned() != surviving {
                return Err("pinned() differs from the surviving key set".into());
            }
            if bs.len() != model.len() {
                return Err("store holds unmodeled blocks after gc".into());
            }
            let bytes: usize = model.values().map(|(l, _)| *l).sum();
            if bs.bytes_stored() != bytes {
                return Err("bytes_stored drifted from surviving payloads".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// UnpinAndGc fault ≡ manual unpin + gc composition: the scenario fault
// is exactly the two Node calls, with no hidden side channel — the
// whole cluster evolves bit-identically either way
// ---------------------------------------------------------------------------

#[test]
fn prop_unpin_and_gc_fault_equals_manual_composition() {
    use peersdb::peersdb::NodeConfig;
    use peersdb::sim::harness::{self, build_cluster, contribute, PeerSpec};
    use peersdb::sim::model::NetModel;
    use peersdb::sim::regions::ALL;

    check(
        "unpin_and_gc_composition",
        |r| (r.next_u64(), [0usize, 2, 3][r.range(0, 3)]),
        |(seed, victim)| {
            let run = |fused: bool| {
                let specs: Vec<PeerSpec> = (0..4)
                    .map(|i| PeerSpec {
                        region: ALL[i % ALL.len()],
                        start_at: Nanos((i as u64) * 100_000_000),
                        cfg: NodeConfig {
                            repair_interval: Duration::from_secs(5),
                            replication_target: 2,
                            ..NodeConfig::default()
                        },
                        ..Default::default()
                    })
                    .collect();
                let mut cluster = build_cluster(*seed, NetModel::default(), specs);
                cluster.run_for(Duration::from_secs(10));
                let mut rng = Rng::new(seed ^ 0xD0);
                let (file, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, 1, 25);
                let cid = contribute(&mut cluster, 1, &file, "spark-sort");
                cluster.run_for(Duration::from_secs(20));
                if fused {
                    harness::unpin_and_gc(&mut cluster, *victim);
                } else {
                    // The same two Node calls the fault makes, issued as
                    // separate injections at the same virtual instant.
                    cluster.with_node(*victim, |n, now, out| {
                        n.unpin_contribution_data(now, out);
                    });
                    cluster.with_node(*victim, |n, _, _| {
                        n.collect_garbage();
                    });
                }
                cluster.run_for(Duration::from_secs(40));
                (
                    cluster.stats.clone(),
                    cluster.now(),
                    cluster.node(0).contributions.digest(),
                    cluster.node(*victim).bs.pinned(),
                    cluster.node(*victim).metrics.counter("blocks_gcd"),
                    chunker::has_file(&cluster.node(*victim).bs, &cid),
                )
            };
            let fused = run(true);
            let composed = run(false);
            if fused != composed {
                return Err(format!(
                    "UnpinAndGc diverged from its manual composition:\n  \
                     fused:    {:?}\n  composed: {:?}",
                    fused.0, composed.0
                ));
            }
            if fused.4 == 0 {
                return Err("unpin+gc collected nothing".into());
            }
            if fused.5 {
                return Err("victim re-replicated deliberately dropped data".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Quorum: decisions always satisfy the agreement threshold
// ---------------------------------------------------------------------------

#[test]
fn prop_quorum_decisions_meet_agreement() {
    check_with_rng(
        "quorum_decisions",
        |r| (r.range(1, 8), r.range(1, 8), r.f64_range(0.5, 1.0)),
        |(fanout, needed, agreement), rng| {
            let cfg = QuorumConfig {
                fanout: *fanout,
                responses_needed: *needed,
                agreement: *agreement,
                timeout: Duration::from_secs(5),
                min_force_verdicts: 1,
            };
            let peers: Vec<PeerId> = (0..*fanout).map(|_| PeerId::from_rng(rng)).collect();
            let mut vote = VoteState::new(Nanos(0), peers.clone());
            let mut verdicts = Vec::new();
            for p in &peers {
                if rng.chance(0.7) {
                    let v = [Verdict::Valid, Verdict::Invalid][rng.range(0, 2)];
                    verdicts.push(v);
                    vote.record(*p, Some((v, rng.f64())));
                } else {
                    vote.record(*p, None);
                }
            }
            for force in [false, true] {
                if let Some(VoteOutcome::Decided { verdict, responses, .. }) =
                    vote.tally(&cfg, force)
                {
                    let n_match = verdicts.iter().filter(|v| **v == verdict).count();
                    let frac = n_match as f64 / verdicts.len() as f64;
                    if frac + 1e-9 < *agreement {
                        return Err(format!(
                            "decided {verdict:?} with only {frac:.2} agreement (< {agreement})"
                        ));
                    }
                    if responses > peers.len() {
                        return Err("responses exceed asked".into());
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Batch queue: no task lost, no task duplicated
// ---------------------------------------------------------------------------

#[test]
fn prop_batch_queue_conserves_tasks() {
    check_with_rng(
        "batch_queue_conserves",
        |r| (r.range(1, 20), r.range(1, 50)),
        |(batch_size, n_tasks), rng| {
            let mut q = BatchQueue::new(*batch_size);
            let cost = CostModel::Constant { ns: 10 };
            let mut enqueued = Vec::new();
            let mut completed = Vec::new();
            let mut in_flight: Vec<u64> = Vec::new();
            let mut t = 0u64;
            for i in 0..*n_tasks {
                let cid = Cid::of_raw(&(i as u64).to_le_bytes());
                enqueued.push(cid);
                q.enqueue(Task { data_cid: cid, size_bytes: rng.gen_range(10_000) });
                t += 1;
                // Randomly start/complete batches (one at a time enforced).
                if let Some((id, _)) = q.maybe_start(Nanos(t), &cost, rng.chance(0.3)) {
                    in_flight.push(id);
                }
                if rng.chance(0.5) {
                    if let Some(id) = in_flight.pop() {
                        let (tasks, _) = q.complete(id).ok_or("lost batch")?;
                        completed.extend(tasks.into_iter().map(|t| t.data_cid));
                    }
                }
            }
            // Drain.
            loop {
                if let Some(id) = in_flight.pop() {
                    let (tasks, _) = q.complete(id).ok_or("lost batch")?;
                    completed.extend(tasks.into_iter().map(|t| t.data_cid));
                    continue;
                }
                match q.maybe_start(Nanos(t), &cost, true) {
                    Some((id, _)) => in_flight.push(id),
                    None => {
                        if q.pending_len() == 0 && q.in_flight_len() == 0 {
                            break;
                        }
                        return Err("queue stuck".into());
                    }
                }
            }
            let mut a = enqueued.clone();
            let mut b = completed.clone();
            a.sort();
            b.sort();
            if a != b {
                return Err(format!("conservation violated: {} in, {} out", a.len(), b.len()));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Directed link-state plane: symmetric faults are composed from directed
// primitives, and a unit latency factor is indistinguishable from no
// override at all (same deliveries, same RNG consumption, same stats)
// ---------------------------------------------------------------------------

/// Minimal chatty runner for link-plane properties: pings every peer at
/// start and echoes hop-limited replies, so traffic crosses every
/// directed link a bounded number of times.
struct Chatter {
    id: PeerId,
    peers: Vec<PeerId>,
    got: Vec<(Nanos, u64)>,
}

impl Runner for Chatter {
    type Msg = u64;

    fn id(&self) -> PeerId {
        self.id
    }

    fn on_start(&mut self, _now: Nanos, out: &mut Outbox<u64>) {
        for p in &self.peers {
            out.send(*p, 1);
        }
    }

    fn on_message(&mut self, now: Nanos, from: PeerId, msg: u64, out: &mut Outbox<u64>) {
        self.got.push((now, msg));
        if msg < 6 {
            out.send(from, msg + 1);
        }
    }

    fn on_timer(&mut self, _now: Nanos, _token: u64, _out: &mut Outbox<u64>) {}
}

fn chatter_cluster(seed: u64, n: usize, loss: f64) -> peersdb::sim::Cluster<Chatter> {
    use peersdb::sim::regions::ALL;
    let mut rng = Rng::new(seed);
    let ids: Vec<PeerId> = (0..n).map(|_| PeerId::from_rng(&mut rng)).collect();
    let model = peersdb::sim::NetModel::uniform(30.0, 512.0, 0.05).with_loss(loss);
    let mut c = peersdb::sim::Cluster::new(model, seed);
    for (i, id) in ids.iter().enumerate() {
        let peers = ids.iter().copied().filter(|p| p != id).collect();
        c.add_node(
            Chatter { id: *id, peers, got: vec![] },
            ALL[i % ALL.len()],
            Nanos::ZERO,
        );
    }
    c
}

type ChatterTrace = (peersdb::sim::SimStats, Nanos, Vec<Vec<(Nanos, u64)>>);

fn chatter_trace(c: &peersdb::sim::Cluster<Chatter>) -> ChatterTrace {
    (
        c.stats.clone(),
        c.now(),
        (0..c.len()).map(|i| c.node(i).got.clone()).collect(),
    )
}

#[test]
fn prop_block_pair_equals_two_directed_blocks() {
    check(
        "block_pair_equals_two_directed_blocks",
        |r| (r.next_u64(), r.range(3, 6), r.f64_range(0.0, 0.05)),
        |(seed, n, loss)| {
            let run = |directed: bool| {
                let mut c = chatter_cluster(*seed, *n, *loss);
                if directed {
                    c.block_link(0, 1);
                    c.block_link(1, 0);
                } else {
                    c.block_pair(0, 1);
                }
                c.run_until_idle();
                chatter_trace(&c)
            };
            let pair = run(false);
            let composed = run(true);
            if pair != composed {
                return Err(format!(
                    "BlockPair diverged from its directed composition:\n  \
                     pair:     {:?}\n  composed: {:?}",
                    pair.0, composed.0
                ));
            }
            if pair.0.msgs_dropped_blocked == 0 {
                return Err("blocked pair never dropped a message".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_slow_link_unit_factor_is_noop() {
    check(
        "slow_link_unit_factor_is_noop",
        |r| (r.next_u64(), r.range(2, 5), r.f64_range(0.0, 0.05)),
        |(seed, n, loss)| {
            let nominal = {
                let mut c = chatter_cluster(*seed, *n, *loss);
                c.run_until_idle();
                chatter_trace(&c)
            };
            let unit = {
                let mut c = chatter_cluster(*seed, *n, *loss);
                // Explicit 1.0 multipliers on every directed link: the
                // probe path runs on every dispatch, and must change
                // nothing — deliveries, times, stats, RNG draws.
                for i in 0..*n {
                    for j in 0..*n {
                        if i != j {
                            c.set_link_latency_factor(i, j, 1.0);
                        }
                    }
                }
                if c.overridden_links() == 0 {
                    return Err("unit factors must keep the probe path live".into());
                }
                c.run_until_idle();
                chatter_trace(&c)
            };
            if nominal != unit {
                return Err(format!(
                    "unit latency factor changed behavior:\n  nominal: {:?}\n  unit:    {:?}",
                    nominal.0, unit.0
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Simulator: deterministic given a seed, even under churn and loss
// ---------------------------------------------------------------------------

#[test]
fn prop_sim_runs_are_deterministic() {
    use peersdb::peersdb::NodeConfig;
    use peersdb::sim::harness::{build_cluster, contribute, PeerSpec};
    use peersdb::sim::model::NetModel;
    use peersdb::sim::regions::ALL;

    check(
        "sim_runs_are_deterministic",
        |r| (r.next_u64(), r.range(3, 6)),
        |(seed, n)| {
            let run = || {
                let specs: Vec<PeerSpec> = (0..*n)
                    .map(|i| PeerSpec {
                        region: ALL[i % ALL.len()],
                        start_at: Nanos((i as u64) * 100_000_000),
                        cfg: NodeConfig::default(),
                        ..Default::default()
                    })
                    .collect();
                let mut model = NetModel::default();
                model.loss = 0.02; // failure injection: 2 % message loss
                let mut cluster = build_cluster(*seed, model, specs);
                cluster.run_for(Duration::from_secs(10));
                let mut rng = Rng::new(seed ^ 7);
                let (file, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, 0, 30);
                contribute(&mut cluster, 1, &file, "spark-sort");
                cluster.run_for(Duration::from_secs(30));
                (
                    cluster.stats.msgs_sent,
                    cluster.stats.msgs_delivered,
                    cluster.stats.msgs_dropped_loss,
                    cluster.stats.bytes_sent,
                    cluster.node(0).contributions.digest(),
                )
            };
            let a = run();
            let b = run();
            if a != b {
                return Err(format!("non-deterministic: {a:?} vs {b:?}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Failure injection: convergence despite message loss
// ---------------------------------------------------------------------------

#[test]
fn prop_convergence_under_loss() {
    use peersdb::peersdb::NodeConfig;
    use peersdb::sim::harness::{assert_converged, build_cluster, contribute, PeerSpec};
    use peersdb::sim::model::NetModel;
    use peersdb::sim::regions::ALL;

    check(
        "convergence_under_loss",
        |r| (r.next_u64(), r.f64_range(0.0, 0.10)),
        |(seed, loss)| {
            let specs: Vec<PeerSpec> = (0..4)
                .map(|i| PeerSpec {
                    region: ALL[i % ALL.len()],
                    start_at: Nanos((i as u64) * 200_000_000),
                    cfg: NodeConfig::default(),
                    ..Default::default()
                })
                .collect();
            let mut model = NetModel::default();
            model.loss = *loss;
            let mut cluster = build_cluster(*seed, model, specs);
            cluster.run_for(Duration::from_secs(15));
            let mut rng = Rng::new(seed ^ 13);
            for i in 0..3 {
                let (file, _) = peersdb::modeling::datagen::generate_contribution(&mut rng, i, 20);
                contribute(&mut cluster, 1 + (i as usize % 3), &file, "spark-grep");
                cluster.run_for(Duration::from_secs(5));
            }
            cluster.run_for(Duration::from_secs(240));
            assert_converged(&mut cluster);
            if cluster.node(0).contributions.len() != 3 {
                return Err(format!(
                    "expected 3 contributions, got {} (loss {loss:.2})",
                    cluster.node(0).contributions.len()
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Timer wheel: pop order identical to the legacy BinaryHeap event queue
// ---------------------------------------------------------------------------

/// Lockstep differential test for the DES event queue swap: a
/// [`TimerWheel`] and the legacy `BinaryHeap` (the reference model,
/// via [`Scheduled`]'s reversed `Ord`) are driven through the same
/// random interleaving of pushes, pops, and tombstone compactions.
/// Every pop must yield the identical `(at, seq, item)` triple and the
/// lengths must track exactly — the property the digest-stability of
/// every pre-existing bank scenario rests on.
#[test]
fn prop_timer_wheel_matches_legacy_heap_reference() {
    use peersdb::sim::wheel::{Scheduled, TimerWheel, SLOTS, SLOT_NS};
    use std::collections::BinaryHeap;

    check_with_rng(
        "timer_wheel_matches_legacy_heap",
        |r| {
            (
                r.range(10, 400), // op count
                r.range(1, 4),    // horizon in wheel spans (>1 exercises overflow)
                r.range(2, 6),    // congruence classes for the dead predicate
            )
        },
        |(ops, horizon, modulus), rng| {
            let span = SLOT_NS * SLOTS as u64 * *horizon as u64;
            let m = *modulus as u64;
            let mut wheel: TimerWheel<u64> = TimerWheel::new();
            let mut heap: BinaryHeap<Scheduled<u64>> = BinaryHeap::new();
            let mut seq = 0u64;
            let mut item = 0u64;
            for _ in 0..*ops {
                match rng.range(0, 100) {
                    // Push (~55%): anywhere in the horizon, including the
                    // past relative to entries already popped.
                    0..=54 => {
                        let at = Nanos(rng.gen_range(span));
                        wheel.push(at, item);
                        heap.push(Scheduled { at, seq, item });
                        seq += 1;
                        item += 1;
                    }
                    // Pop (~35%): the verdicts must be identical.
                    55..=89 => {
                        let got = wheel.pop().map(|s| (s.at, s.seq, s.item));
                        let want = heap.pop().map(|s| (s.at, s.seq, s.item));
                        if got != want {
                            return Err(format!("pop diverged: wheel {got:?} vs heap {want:?}"));
                        }
                    }
                    // Compact (~10%): kill one congruence class of items —
                    // the tombstone shape the DES uses for crashed nodes.
                    _ => {
                        let dead = rng.gen_range(m);
                        let removed = wheel.compact(|v| v % m == dead);
                        let before = heap.len();
                        heap.retain(|s| s.item % m != dead);
                        if removed != before - heap.len() {
                            return Err(format!(
                                "compact removed {removed}, reference removed {}",
                                before - heap.len()
                            ));
                        }
                    }
                }
                if wheel.len() != heap.len() {
                    return Err(format!(
                        "len diverged: wheel {} vs heap {}",
                        wheel.len(),
                        heap.len()
                    ));
                }
            }
            // Drain the tails: the remaining order must agree too.
            while let Some(want) = heap.pop() {
                match wheel.pop() {
                    Some(got) if (got.at, got.seq, got.item) == (want.at, want.seq, want.item) => {}
                    other => {
                        return Err(format!(
                            "drain diverged: wheel {:?} vs heap {:?}",
                            other.map(|s| (s.at, s.seq, s.item)),
                            (want.at, want.seq, want.item)
                        ));
                    }
                }
            }
            if !wheel.is_empty() {
                return Err("wheel holds entries the reference does not".into());
            }
            Ok(())
        },
    );
}

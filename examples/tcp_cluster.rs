//! Real-deployment path: the same PeersDB nodes over actual TCP sockets
//! on loopback, driven through the HTTP API — no simulator involved.
//!
//! ```bash
//! cargo run --release --example tcp_cluster
//! ```
//!
//! The whole flow lives in `peersdb::sim::parity::tcp_cluster_demo`,
//! which `tests/tcp.rs` also runs (quietly) as a release-gated
//! integration test — so this example is verified in CI and can never
//! silently rot.

fn main() -> anyhow::Result<()> {
    peersdb::sim::parity::tcp_cluster_demo(true)
}

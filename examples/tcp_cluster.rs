//! Real-deployment path: the same PeersDB nodes over actual TCP sockets
//! on loopback, driven through the HTTP API — no simulator involved.
//!
//! ```bash
//! cargo run --release --example tcp_cluster
//! ```

use peersdb::api::http::{http_get, http_post, HttpServer};
use peersdb::codec::json::Json;
use peersdb::modeling::datagen;
use peersdb::net::tcp::{Directory, TcpNode};
use peersdb::net::PeerId;
use peersdb::peersdb::{Node, NodeConfig};
use peersdb::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(3);
    let dir = Directory::new();

    // Root node.
    let root_id = PeerId::from_rng(&mut rng);
    let root = Arc::new(TcpNode::start(
        Node::new(root_id, NodeConfig::default(), rng.next_u64()),
        dir.clone(),
    )?);
    println!("root {} on {}", root_id.short(), root.addr);

    // Three joining peers.
    let mut peers = Vec::new();
    for i in 0..3 {
        let id = PeerId::from_rng(&mut rng);
        let cfg = NodeConfig { bootstrap: Some(root_id), ..NodeConfig::default() };
        let node = Node::new(id, cfg, rng.next_u64());
        let tcp = Arc::new(TcpNode::start(node, dir.clone())?);
        println!("peer {i} {} on {}", id.short(), tcp.addr);
        peers.push(tcp);
    }

    // Wait for bootstrap over real sockets.
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let ready = peers
            .iter()
            .filter(|p| p.call_sync(|n, _, _| n.is_bootstrapped()))
            .count();
        if ready == peers.len() {
            break;
        }
        if Instant::now() > deadline {
            anyhow::bail!("bootstrap timed out ({ready}/3 ready)");
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("all peers bootstrapped over TCP");

    // HTTP API on peer 0 (the prototype's access path).
    let http = HttpServer::start(peers[0].clone())?;
    println!("http api on http://{}", http.addr);
    let (file, _) = datagen::generate_contribution(&mut rng, 2, 100);
    let (code, body) = http_post(
        http.addr,
        "/contributions?workload=spark-pagerank&platform=loopback",
        &file,
    )?;
    anyhow::ensure!(code == 200, "contribute failed: {code}");
    let cid = Json::parse(std::str::from_utf8(&body)?)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .path("cid")
        .and_then(|v| v.as_str())
        .unwrap()
        .to_string();
    println!("contributed via HTTP: cid {}", &cid[..16]);

    // The contribution replicates to every other peer through real
    // sockets (pubsub → log entry fetch → data fetch).
    let cid_parsed = peersdb::cid::Cid::parse(&cid).unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let have = peers
            .iter()
            .map(|p| p.call_sync(move |n, _, _| n.get_file(&cid_parsed).is_some()))
            .filter(|b| *b)
            .count();
        let root_has = root.call_sync(move |n, _, _| n.get_file(&cid_parsed).is_some());
        if have == peers.len() && root_has {
            break;
        }
        if Instant::now() > deadline {
            anyhow::bail!("replication timed out ({have}/3 peers + root {root_has})");
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("replicated to root + all 3 peers over TCP");

    // Check status endpoint.
    let (code, body) = http_get(http.addr, "/status")?;
    anyhow::ensure!(code == 200);
    println!("status: {}", String::from_utf8_lossy(&body));

    http.stop();
    for p in peers {
        match Arc::try_unwrap(p) {
            Ok(t) => t.stop(),
            Err(_) => {}
        }
    }
    if let Ok(t) = Arc::try_unwrap(root) {
        t.stop();
    }
    println!("tcp_cluster OK");
    Ok(())
}

//! Churn resilience: peers disconnect and reconnect randomly while data
//! is being distributed (the Testground `fuzz` scenario of §IV-B),
//! and the layer still converges.
//!
//! ```bash
//! cargo run --release --example churn_resilience
//! ```

use peersdb::modeling::datagen;
use peersdb::peersdb::NodeConfig;
use peersdb::sim::harness;
use peersdb::util::time::Duration;
use peersdb::util::Rng;

fn main() {
    let n = 10;
    let mut cluster =
        harness::paper_cluster(41, n, Duration::from_millis(300), |_| NodeConfig::default());
    cluster.run_for(Duration::from_secs(15));
    println!("cluster of {n} peers up");

    let mut rng = Rng::new(42);
    let total_contribs = 30;
    let mut offline: Vec<usize> = Vec::new();
    for i in 0..total_contribs {
        // Random churn: ~20% chance per round to kill a random non-root
        // peer; ~50% chance to revive one.
        if rng.chance(0.2) && offline.len() < n / 3 {
            let victim = rng.range(1, n);
            if !offline.contains(&victim) {
                cluster.set_offline(victim);
                offline.push(victim);
                println!("t={} peer {victim} disconnected", cluster.now());
            }
        }
        if rng.chance(0.5) {
            if let Some(back) = offline.pop() {
                cluster.set_online(back);
                println!("t={} peer {back} reconnected", cluster.now());
            }
        }
        // Contributions keep flowing from random online peers.
        let wl = (i % 6) as u32;
        let (file, _) = datagen::generate_contribution(&mut rng, wl, 60);
        let mut contributor = rng.range(1, n);
        while offline.contains(&contributor) {
            contributor = rng.range(1, n);
        }
        harness::contribute(&mut cluster, contributor, &file, datagen::WORKLOADS[wl as usize]);
        cluster.run_for(Duration::from_secs(2));
    }
    // Revive everyone and let anti-entropy finish.
    for peer in offline.drain(..) {
        cluster.set_online(peer);
        println!("t={} peer {peer} reconnected (final)", cluster.now());
    }
    cluster.run_for(Duration::from_secs(180));

    harness::assert_converged(&mut cluster);
    println!(
        "\nall {} stores converged on {} contributions despite churn",
        n,
        cluster.node(0).contributions.len()
    );
    println!(
        "transport: {} delivered, {} dropped to offline peers, {} blocked",
        cluster.stats.msgs_delivered, cluster.stats.msgs_dropped_offline, cluster.stats.msgs_dropped_blocked
    );
    assert_eq!(cluster.node(0).contributions.len(), total_contribs);
    println!("churn_resilience OK");
}

//! END-TO-END DRIVER: the full paper pipeline on a real small workload.
//!
//! 12 organizations run distributed dataflow jobs and share performance
//! data through the P2P distribution layer. Afterwards one peer runs the
//! §III-D performance-modeling workflow: assemble training data from the
//! replicated contributions store, train the AOT-compiled MLP runtime
//! predictor via PJRT for a few hundred steps (logging the loss curve),
//! and evaluate prediction error — **collaborative vs local-only**, the
//! paper's headline motivation.
//!
//! ```bash
//! make artifacts && cargo run --release --example collaborative_modeling
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use peersdb::modeling::datagen::{self, TraceRow, WORKLOADS};
use peersdb::modeling::features::{encode_batch, DIM};
use peersdb::modeling::workflow;
use peersdb::peersdb::NodeConfig;
use peersdb::runtime::batching::padded_batches;
use peersdb::runtime::PerfModel;
use peersdb::sim::harness;
use peersdb::util::time::Duration;
use peersdb::util::Rng;

const PEERS: usize = 12;
const FILES_PER_PEER: usize = 6;
const ROWS_PER_FILE: usize = 40;
const EPOCHS: usize = 40;
const LR: f32 = 0.05;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(2024);

    // ---- Phase 1: the data distribution layer at work -------------------
    println!("== phase 1: P2P data sharing across {PEERS} peers ==");
    let mut cluster =
        harness::paper_cluster(11, PEERS, Duration::from_millis(400), |_| NodeConfig::default());
    cluster.run_for(Duration::from_secs(20));

    // Each peer observes only ONE workload type (the realistic silo:
    // no single org runs everything) and contributes its trace files.
    let mut local_rows_per_peer: Vec<Vec<TraceRow>> = vec![Vec::new(); PEERS];
    for peer in 1..PEERS {
        let wl = ((peer - 1) % WORKLOADS.len()) as u32;
        for _ in 0..FILES_PER_PEER {
            let (file, rows) = datagen::generate_contribution(&mut rng, wl, ROWS_PER_FILE);
            local_rows_per_peer[peer].extend(rows);
            harness::contribute(&mut cluster, peer, &file, WORKLOADS[wl as usize]);
            cluster.run_for(Duration::from_millis(500));
        }
    }
    cluster.run_for(Duration::from_secs(60));
    harness::assert_converged(&mut cluster);
    let total = cluster.node(0).contributions.len();
    println!("   {total} contributions fully replicated on all {PEERS} peers");
    let repl = cluster
        .node(3)
        .metrics
        .summary("replication_ms")
        .map(|s| (s.mean(), s.max()))
        .unwrap_or((f64::NAN, f64::NAN));
    println!("   peer-3 replication latency: mean {:.0} ms, max {:.0} ms", repl.0, repl.1);

    // ---- Phase 2: the §III-D modeling workflow on peer 3 ----------------
    println!("\n== phase 2: performance modeling on peer 3 (PJRT, AOT artifacts) ==");
    let mut model = PerfModel::load("artifacts")?;
    println!("   model loaded: {} trainable params, batch {}", model.param_count(), model.meta.batch);

    // Held-out evaluation set: fresh draws from EVERY workload's ground
    // truth — what peer 3 will be asked to predict in production.
    let test_rows: Vec<TraceRow> = (0..WORKLOADS.len() as u32)
        .flat_map(|wl| (0..60).map(move |_| (wl, ())))
        .scan(Rng::new(555), |r, (wl, _)| Some(datagen::sample_row(r, wl)))
        .collect();

    // Local-only: what peer 3 saw itself (one workload).
    let local_rows = local_rows_per_peer[3].clone();
    // Collaborative: everything the distribution layer brought in.
    let collab_rows = workflow::assemble_from_node(cluster.node(3), None, &[]);
    println!("   training data: local-only {} rows | collaborative {} rows", local_rows.len(), collab_rows.len());

    // Loss curve for the collaborative run (a few hundred steps).
    {
        model.reset()?;
        let mut shuffled = collab_rows.clone();
        let mut r = Rng::new(9);
        let mut step = 0usize;
        println!("   loss curve (collaborative):");
        for epoch in 0..EPOCHS {
            r.shuffle(&mut shuffled);
            let (xs, ys) = encode_batch(&shuffled);
            for (bx, by, bm) in padded_batches(&xs, &ys, DIM, model.meta.batch) {
                let loss = model.train_step(&bx, &by, &bm, LR)?;
                if step % 40 == 0 {
                    println!("     step {step:4}  loss {loss:.4}");
                }
                step += 1;
            }
            let _ = epoch;
        }
        println!("     step {step:4}  (final)");
    }

    let (local, collab) = workflow::collaboration_benefit(
        &mut model,
        &local_rows,
        &collab_rows,
        &test_rows,
        EPOCHS,
        LR,
        77,
    )?;

    println!("\n== results (held-out, all workloads) ==");
    println!(
        "   local-only    : {:4} rows  RMSE(ln rt) {:.3}  MAPE {:5.1}%",
        local.train_rows,
        local.rmse_log,
        local.mape * 100.0
    );
    println!(
        "   collaborative : {:4} rows  RMSE(ln rt) {:.3}  MAPE {:5.1}%",
        collab.train_rows,
        collab.rmse_log,
        collab.mape * 100.0
    );
    let gain = local.rmse_log / collab.rmse_log;
    println!("   collaboration improves RMSE by {gain:.1}x");
    assert!(gain > 1.5, "collaboration should help substantially");
    println!("\ncollaborative_modeling OK");
    Ok(())
}

//! Quickstart: spin up a small simulated PeersDB cluster, share a
//! performance-data contribution, and watch it replicate.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use peersdb::modeling::datagen;
use peersdb::peersdb::NodeConfig;
use peersdb::sim::harness;
use peersdb::util::time::Duration;
use peersdb::util::Rng;

fn main() {
    // 1. A five-peer cluster: one root in asia-east2 (the paper's layout),
    //    four peers joining through it from other regions.
    let mut cluster = harness::paper_cluster(7, 5, Duration::from_millis(300), |_| NodeConfig::default());
    cluster.run_for(Duration::from_secs(15));
    println!("cluster up: {} peers, all bootstrapped", cluster.len());

    // 2. Peer 2 finishes a Spark job and contributes its performance data
    //    (workload monitoring rows, gzipped JSON — ~9 KB like the paper's
    //    corpus).
    let mut rng = Rng::new(1);
    let (file, rows) = datagen::generate_contribution(&mut rng, 0, 120);
    println!("contributing {} runtime observations ({} bytes compressed)", rows.len(), file.len());
    let cid = harness::contribute(&mut cluster, 2, &file, "spark-sort");
    println!("contribution cid: {cid}");

    // 3. Replication is automatic: the contribution record spreads via
    //    pubsub + the log CRDT; the data file via bitswap; provider
    //    records land in the DHT.
    cluster.run_for(Duration::from_secs(20));
    harness::assert_converged(&mut cluster);
    for i in 0..cluster.len() {
        let n = cluster.node(i);
        println!(
            "peer {i} [{}]: {} contribution(s), file locally available: {}",
            cluster.region_of(i).name(),
            n.contributions.len(),
            n.get_file(&cid).is_some()
        );
    }

    // 4. Query the store like a database (the OrbitDB-style API).
    let hits = cluster.node(4).query_contributions(|c| c.workload == "spark-sort");
    println!("peer 4 query spark-sort → {} hit(s)", hits.len());

    // 5. Replication latency measured by the layer itself.
    for i in 1..cluster.len() {
        let mean = cluster
            .node(i)
            .metrics
            .summary("replication_ms")
            .map(|s| s.mean())
            .unwrap_or(f64::NAN);
        println!("peer {i} replication latency: {mean:.0} ms");
    }
    println!("quickstart OK");
}

//! Collaborative data validation with the AOT model-backed validator.
//!
//! A cluster shares good and corrupted contributions; every node runs the
//! two-stage validation pipeline (structural checks + the compiled k-NN
//! novelty scorer served by a PJRT model-server thread). Nodes first
//! consult the network (quorum voting); once verdicts exist, late
//! validators adopt them without re-computing (§III-C).
//!
//! ```bash
//! make artifacts && cargo run --release --example validation_quorum
//! ```

use peersdb::modeling::datagen::{self, WORKLOADS};
use peersdb::modeling::features::encode_row;
use peersdb::modeling::validator::ModelServer;
use peersdb::peersdb::{NodeConfig, NodeEvent, ValidationSource};
use peersdb::sim::harness::{self, PeerSpec};
use peersdb::sim::model::NetModel;
use peersdb::sim::regions::{Region, ALL};
use peersdb::stores::documents::Verdict;
use peersdb::util::time::{Duration, Nanos};
use peersdb::util::Rng;
use peersdb::validation::CostModel;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(31);

    // Trusted reference rows for the novelty scorer: a sample of
    // known-good observations from every workload.
    let reference: Vec<[f32; 8]> = (0..WORKLOADS.len() as u32)
        .flat_map(|wl| {
            let mut r = Rng::new(1000 + wl as u64);
            (0..64)
                .map(move |_| encode_row(&datagen::sample_row(&mut r, wl)))
                .collect::<Vec<_>>()
        })
        .collect();
    // Threshold calibrated on held-out good data: p95 of good-row kNN
    // scores is ≈0.32, max ≈0.89; genuine feature outliers score in the
    // hundreds-to-thousands (see EXPERIMENTS.md §Validation).
    let server = ModelServer::spawn("artifacts".into(), reference, 1.0)?;
    println!("model server up (AOT knn_score via PJRT)");

    let n = 8;
    let mk_cfg = || NodeConfig {
        auto_validate: true,
        cost_model: CostModel::Linear { base_ns: 5_000_000, ns_per_kb: 100_000.0 },
        ..NodeConfig::default()
    };
    let mut specs: Vec<PeerSpec> = (0..n)
        .map(|i| PeerSpec {
            region: ALL[i % ALL.len()],
            start_at: Nanos(Duration::from_millis(150).0 * i as u64),
            cfg: mk_cfg(),
            validator: Some(Box::new(server.validator())),
            ..Default::default()
        })
        .collect();
    // A late joiner (index n): arrives after the network has validated
    // everything, so its quorum queries find stored verdicts and it
    // adopts them instead of validating locally (§III-C).
    specs.push(PeerSpec {
        region: Region::UsWest1,
        start_at: Nanos(Duration::from_secs(300).0),
        cfg: mk_cfg(),
        validator: Some(Box::new(server.validator())),
        ..Default::default()
    });
    let mut cluster = harness::build_cluster(31, NetModel::default(), specs);
    cluster.run_for(Duration::from_secs(10));

    // Share 6 good files and 3 corrupted ones (subtly corrupted: rows
    // whose runtimes are implausible for their configuration).
    let mut good_cids = Vec::new();
    let mut bad_cids = Vec::new();
    for i in 0..6 {
        let wl = (i % WORKLOADS.len()) as u32;
        let (file, _) = datagen::generate_contribution(&mut rng, wl, 80);
        good_cids.push(harness::contribute(&mut cluster, 1 + (i % (n - 1)), &file, WORKLOADS[wl as usize]));
        cluster.run_for(Duration::from_secs(3));
    }
    for i in 0..3 {
        let wl = (i % WORKLOADS.len()) as u32;
        let (file, _) = datagen::generate_corrupt_contribution(&mut rng, wl, 80, 0.6);
        bad_cids.push(harness::contribute(&mut cluster, 1 + (i % (n - 1)), &file, WORKLOADS[wl as usize]));
        cluster.run_for(Duration::from_secs(3));
    }
    // Run past the late joiner's start; it syncs history and validates
    // everything — by quorum, since verdicts now exist in the network.
    cluster.run_for(Duration::from_secs(400));

    let events = harness::drain_events(&mut cluster);
    let mut det_good = 0;
    let mut det_bad = 0;
    let mut by_network = 0;
    let mut by_local = 0;
    for (_, e) in &events {
        if let NodeEvent::ValidationDone { data_cid, verdict, source, .. } = e {
            if good_cids.contains(data_cid) && *verdict == Verdict::Valid {
                det_good += 1;
            }
            if bad_cids.contains(data_cid) && *verdict == Verdict::Invalid {
                det_bad += 1;
            }
            match source {
                ValidationSource::Network => by_network += 1,
                ValidationSource::Local => by_local += 1,
            }
        }
    }
    println!("\n== validation outcomes across the cluster ==");
    println!("   good contributions confirmed valid : {det_good}");
    println!("   corrupted contributions flagged    : {det_bad}");
    println!("   verdicts computed locally          : {by_local}");
    println!("   verdicts adopted from the network  : {by_network}");

    // Every node should now refuse to train on the flagged data.
    let filtered = peersdb::modeling::workflow::assemble_from_node(cluster.node(2), None, &[]);
    let unfiltered: usize = cluster
        .node(2)
        .query_contributions(|_| true)
        .iter()
        .map(|c| c.size_bytes as usize)
        .count();
    println!("   peer-2 training assembly: {unfiltered} contributions stored, rows used only from valid ones ({} rows)", filtered.len());

    assert!(det_good >= (n - 1) * 5, "good data must be accepted");
    assert!(det_bad >= (n - 1) * 2, "corrupt data must be flagged");
    server.stop();
    println!("validation_quorum OK");
    Ok(())
}

#[allow(dead_code)]
fn region_name(r: Region) -> &'static str {
    r.name()
}

"""Layer-2: the collaborative performance model, in JAX, on Pallas kernels.

The model is a 3-layer MLP runtime predictor over the 8-dim feature
layout defined in ``rust/src/modeling/features.rs`` (kept in sync by
hand; the AOT artifacts freeze it):

    x[B, 8] -> dense(64, relu) -> dense(64, relu) -> dense(1) -> ln(rt)

All dense layers run on the fused Pallas matmul kernel in both the
forward pass and the backward pass (custom VJP below: dx and dw are
matmuls on the same kernel). Additionally :func:`knn_score` is the
validation scorer (pairwise-distance kernel + top-k).

Targets are ln(runtime_seconds); the loss is a mask-weighted MSE so the
Rust side can pad partial batches to the compiled batch size.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import matmul, pairwise_sqdist

# --- Compiled shapes (the AOT contract; rust/src/runtime asserts these) ---
BATCH = 256
FEATURES = 8
HIDDEN = 64
REFSET = 512
KNN_K = 8


# --------------------------------------------------------------------------
# Dense layer with custom VJP — forward AND backward on the Pallas kernel.
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, relu: bool):
    return matmul(x, w, b, activation="relu" if relu else None)


def _dense_fwd(x, w, b, relu: bool):
    out = matmul(x, w, b, activation="relu" if relu else None)
    return out, (x, w, out)


def _dense_bwd(relu: bool, res, g):
    x, w, out = res
    if relu:
        g = g * (out > 0).astype(g.dtype)
    # dx = g @ w.T ; dw = x.T @ g — the same fused kernel, no epilogue.
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------

def init_params(key=None):
    """He-init MLP parameters (deterministic default key)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = (2.0 / FEATURES) ** 0.5
    s2 = (2.0 / HIDDEN) ** 0.5
    return (
        jax.random.normal(k1, (FEATURES, HIDDEN), jnp.float32) * s1,
        jnp.zeros((HIDDEN,), jnp.float32),
        jax.random.normal(k2, (HIDDEN, HIDDEN), jnp.float32) * s2,
        jnp.zeros((HIDDEN,), jnp.float32),
        jax.random.normal(k3, (HIDDEN, 1), jnp.float32) * s2,
        jnp.zeros((1,), jnp.float32),
    )


def mlp(params, x):
    """Forward pass → predicted ln(runtime), shape (B,)."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = dense(x, w1, b1, True)
    h2 = dense(h1, w2, b2, True)
    out = dense(h2, w3, b3, False)
    return out[:, 0]


def masked_mse(params, x, y, mask):
    pred = mlp(params, x)
    se = (pred - y) ** 2 * mask
    return jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)


def train_step(params, x, y, mask, lr):
    """One SGD step; returns (new_params, loss). AOT entry point."""
    loss, grads = jax.value_and_grad(masked_mse)(params, x, y, mask)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return new_params, loss


def predict(params, x):
    """Predicted ln(runtime) for a feature batch. AOT entry point."""
    return mlp(params, x)


def knn_score(x, refs):
    """Mean squared distance to the K nearest reference rows — the
    validation novelty score (higher = more anomalous). AOT entry point.

    Implemented with a full sort rather than ``lax.top_k``: topk lowers to
    a `topk(..., largest=true)` HLO attribute that xla_extension 0.5.1's
    text parser rejects, while `sort` round-trips fine.
    """
    d = pairwise_sqdist(x, refs)
    return jnp.mean(jnp.sort(d, axis=1)[:, :KNN_K], axis=1)

"""Fused tiled matmul kernel: ``act(x @ w + b)``.

The hot-spot of the performance model's forward *and* backward passes
(dx = g @ w.T and dw = x.T @ g are matmuls too). One Pallas kernel
covers all of them, with optional bias-add and ReLU fused into the
epilogue so each output tile is written exactly once.

TPU mapping (DESIGN.md §Hardware-Adaptation):

* grid = (M/bm, N/bn); each step loads an (bm, K) LHS tile and a (K, bn)
  RHS tile from HBM into VMEM via BlockSpec, multiplies on the MXU with
  f32 accumulation, applies the epilogue in the VPU, and writes the
  (bm, bn) tile back — a classic output-stationary schedule.
* bm/bn default to 128 (MXU-native); K is kept whole per step because
  the model's contraction dims (8..128) always fit VMEM. For the
  compiled shapes the per-step working set is
  bm*K + K*bn + bm*bn floats ≤ ~192 KiB — far inside VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, use_bias: bool, activation: str):
    x = x_ref[...]
    w = w_ref[...]
    # MXU with f32 accumulation regardless of input dtype.
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if use_bias:
        acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _pick_block(dim: int, preferred: int = 128) -> int:
    """Largest divisor of ``dim`` that is ≤ preferred (prefers 128/64/...)."""
    for cand in (preferred, 64, 32, 16, 8, 4, 2, 1):
        if cand <= dim and dim % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("activation",))
def _matmul_jit(x, w, b, activation):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m)
    bn = _pick_block(n)
    use_bias = b is not None
    kernel = functools.partial(
        _kernel, use_bias=use_bias, activation=activation or "none"
    )
    in_specs = [
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((k, bn), lambda i, j: (0, j)),
    ]
    args = [x, w]
    if use_bias:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j: (j,)))
        args.append(b)
    else:
        # Pallas requires a concrete operand list; pass a dummy scalar
        # that the kernel ignores.
        in_specs.append(pl.BlockSpec((1,), lambda i, j: (0,)))
        args.append(jnp.zeros((1,), x.dtype))
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(*args)


def matmul(x, w, b=None, activation=None):
    """``act(x @ w + b)`` via the Pallas kernel.

    x: (M, K); w: (K, N); b: (N,) or None; activation: None | "relu".
    """
    return _matmul_jit(x, w, b, activation)

"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel test asserts allclose against these; nothing here may import
Pallas.
"""

import jax.numpy as jnp


def matmul_ref(x, w, b=None, activation=None):
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        out = out + b.astype(jnp.float32)[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def pairwise_sqdist_ref(x, refs):
    x = x.astype(jnp.float32)
    refs = refs.astype(jnp.float32)
    diff = x[:, None, :] - refs[None, :, :]
    return jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)


def mlp_ref(params, x):
    """Reference 3-layer MLP forward (f32)."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = jnp.maximum(jnp.dot(x, w1) + b1[None, :], 0.0)
    h2 = jnp.maximum(jnp.dot(h1, w2) + b2[None, :], 0.0)
    return (jnp.dot(h2, w3) + b3[None, :])[:, 0]


def masked_mse_ref(pred, y, mask):
    se = (pred - y) ** 2 * mask
    return jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)


def knn_score_ref(x, refs, k):
    d = pairwise_sqdist_ref(x, refs)
    topk = jnp.sort(d, axis=1)[:, :k]
    return jnp.mean(topk, axis=1)

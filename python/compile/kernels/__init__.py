"""Layer-1 Pallas kernels for the performance-model compute hot-spots.

Everything here is lowered with ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls); correctness is pinned against the
pure-jnp oracles in :mod:`compile.kernels.ref`. Block shapes are chosen
for the TPU memory hierarchy (see DESIGN.md §Hardware-Adaptation):
128-aligned tiles sized to keep each grid step's working set well inside
a ~16 MiB VMEM budget and feed the 128x128 MXU systolic array.
"""

from compile.kernels.matmul import matmul
from compile.kernels.pairwise import pairwise_sqdist

__all__ = ["matmul", "pairwise_sqdist"]

"""Tiled pairwise squared-distance kernel.

Used by the data-validation scorer: each incoming contribution row is
scored by its distance to the k nearest rows of a trusted reference set
(novelty/outlier detection — the "validate data quality as well as the
benefit for performance modeling" routine of §III-C).

dist(i, j) = |x_i|^2 + |r_j|^2 - 2 x_i.r_j

The cross term is an (bm, D) x (D, bn) matmul → MXU; the norms ride in
the VPU epilogue. Grid = (B/bm, R/bn), so arbitrary-size reference sets
stream through VMEM tile by tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.matmul import _pick_block


def _kernel(x_ref, r_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    cross = jnp.dot(x, r.T, preferred_element_type=jnp.float32)
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    rn = jnp.sum(r * r, axis=1, keepdims=True)
    d = xn + rn.T - 2.0 * cross
    # Clamp tiny negatives from cancellation.
    o_ref[...] = jnp.maximum(d, 0.0).astype(o_ref.dtype)


@jax.jit
def pairwise_sqdist(x, refs):
    """Squared euclidean distances: x (B, D), refs (R, D) → (B, R)."""
    b, d = x.shape
    r, d2 = refs.shape
    assert d == d2
    bb = _pick_block(b)
    br = _pick_block(r)
    return pl.pallas_call(
        _kernel,
        grid=(b // bb, r // br),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((br, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, br), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=True,
    )(x, refs)

"""AOT compilation: lower the L2 entry points to HLO *text* artifacts.

HLO text — not ``serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids that xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifacts written to ``--out`` (default ../artifacts):

    init_params.hlo.txt  ()                                  -> (params…)
    train_step.hlo.txt   (params…, x, y, mask, lr)           -> (params…, loss)
    predict.hlo.txt      (params…, x)                        -> (yhat,)
    knn_score.hlo.txt    (x, refs)                           -> (scores,)
    meta.json            shape/layout contract for the rust runtime

Python runs ONCE, at build time; the rust binary is self-contained
afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten_params(params):
    return list(params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    B, D, H, R = model.BATCH, model.FEATURES, model.HIDDEN, model.REFSET
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    param_specs = (
        sd((D, H), f32), sd((H,), f32),
        sd((H, H), f32), sd((H,), f32),
        sd((H, 1), f32), sd((1,), f32),
    )

    def init_fn():
        return model.init_params()

    def train_fn(w1, b1, w2, b2, w3, b3, x, y, mask, lr):
        params, loss = model.train_step((w1, b1, w2, b2, w3, b3), x, y, mask, lr)
        return (*params, loss)

    def predict_fn(w1, b1, w2, b2, w3, b3, x):
        return (model.predict((w1, b1, w2, b2, w3, b3), x),)

    def knn_fn(x, refs):
        return (model.knn_score(x, refs),)

    jobs = [
        ("init_params", init_fn, ()),
        ("train_step", train_fn,
         (*param_specs, sd((B, D), f32), sd((B,), f32), sd((B,), f32), sd((), f32))),
        ("predict", predict_fn, (*param_specs, sd((B, D), f32))),
        ("knn_score", knn_fn, (sd((B, D), f32), sd((R, D), f32))),
    ]
    for name, fn, specs in jobs:
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "batch": B,
        "features": D,
        "hidden": H,
        "refset": R,
        "knn_k": model.KNN_K,
        "param_shapes": [[D, H], [H], [H, H], [H], [H, 1], [1]],
        "target": "ln(runtime_seconds)",
        "interchange": "hlo-text",
    }
    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print("wrote meta.json")


if __name__ == "__main__":
    main()

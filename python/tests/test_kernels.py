"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-128-divisible ones exercising the
block-picker) and dtypes; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, pairwise_sqdist
from compile.kernels import ref

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16, 24, 64, 100, 128, 256])
SMALL = st.sampled_from([1, 2, 4, 8, 16, 64])


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=SMALL, n=DIMS, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k), jnp.float32)
    w = rand(seed + 1, (k, n), jnp.float32)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=SMALL, k=SMALL, n=SMALL, seed=st.integers(0, 2**16))
def test_matmul_bias_relu_fusion(m, k, n, seed):
    x = rand(seed, (m, k), jnp.float32)
    w = rand(seed + 1, (k, n), jnp.float32)
    b = rand(seed + 2, (n,), jnp.float32)
    got = matmul(x, w, b, activation="relu")
    want = ref.matmul_ref(x, w, b, activation="relu")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert (np.asarray(got) >= 0).all(), "relu epilogue"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = rand(0, (128, 8), dtype)
    w = rand(1, (8, 64), dtype)
    got = matmul(x, w).astype(jnp.float32)
    want = ref.matmul_ref(x, w).astype(jnp.float32)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_matmul_compiled_model_shapes():
    # The exact shapes frozen into the artifacts.
    for (m, k, n) in [(256, 8, 64), (256, 64, 64), (256, 64, 1), (64, 256, 64)]:
        x = rand(2, (m, k), jnp.float32)
        w = rand(3, (k, n), jnp.float32)
        np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(b=DIMS, r=DIMS, d=SMALL, seed=st.integers(0, 2**16))
def test_pairwise_matches_ref(b, r, d, seed):
    x = rand(seed, (b, d), jnp.float32)
    refs = rand(seed + 1, (r, d), jnp.float32)
    got = pairwise_sqdist(x, refs)
    want = ref.pairwise_sqdist_ref(x, refs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert (np.asarray(got) >= 0).all()


def test_pairwise_zero_distance_on_self():
    x = rand(7, (16, 8), jnp.float32)
    d = np.asarray(pairwise_sqdist(x, x))
    np.testing.assert_allclose(np.diag(d), np.zeros(16), atol=1e-4)

"""AOT pipeline smoke tests: artifacts exist, are HLO text, and respect
the declared shape contract."""

import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
PY_ROOT = os.path.dirname(HERE)


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=PY_ROOT,
        check=True,
    )
    return out


EXPECTED = ["init_params", "train_step", "predict", "knn_score"]


def test_all_artifacts_written(artifacts):
    for name in EXPECTED:
        path = artifacts / f"{name}.hlo.txt"
        assert path.exists(), name
        text = path.read_text()
        assert "ENTRY" in text and "HloModule" in text, f"{name} is not HLO text"
    meta = json.loads((artifacts / "meta.json").read_text())
    assert meta["batch"] == 256
    assert meta["features"] == 8
    assert meta["interchange"] == "hlo-text"


def test_train_step_signature_shapes(artifacts):
    text = (artifacts / "train_step.hlo.txt").read_text()
    # 6 params + x + y + mask + lr = 10 inputs; outputs 6 params + loss.
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    end = next(i for i in range(start, len(lines)) if lines[i].rstrip() == "}")
    entry = lines[start:end]
    n_inputs = sum(1 for l in entry if "parameter(" in l)
    assert n_inputs == 10, f"expected 10 entry parameters, found {n_inputs}"
    assert "f32[256,8]" in text  # x
    assert "f32[8,64]" in text  # w1


def test_no_custom_calls(artifacts):
    """interpret=True must lower to plain HLO the CPU client can run —
    a Mosaic custom-call here would break the rust runtime."""
    for name in EXPECTED:
        text = (artifacts / f"{name}.hlo.txt").read_text()
        assert "custom-call" not in text or "mosaic" not in text.lower(), name

"""L2 correctness: the JAX model (on Pallas kernels) vs pure-jnp reference,
gradient checks, and training convergence."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def synthetic_batch(key, n=model.BATCH):
    """Features + targets from a known nonlinear function."""
    kx, kn = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.uniform(kx, (n, model.FEATURES), jnp.float32)
    y = (
        2.0 * x[:, 0]
        - 1.5 * x[:, 1] * x[:, 2]
        + jnp.sin(3.0 * x[:, 3])
        + 0.1 * jax.random.normal(kn, (n,))
    )
    mask = jnp.ones((n,), jnp.float32)
    return x, y, mask


def test_forward_matches_pure_jnp():
    params = model.init_params()
    x, _, _ = synthetic_batch(0)
    got = model.mlp(params, x)
    want = ref.mlp_ref(params, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gradients_match_pure_jnp_model():
    """Custom-VJP (Pallas) grads == autodiff grads of the jnp reference."""
    params = model.init_params()
    x, y, mask = synthetic_batch(1)

    def ref_loss(params, x, y, mask):
        pred = ref.mlp_ref(params, x)
        return ref.masked_mse_ref(pred, y, mask)

    g_pallas = jax.grad(model.masked_mse)(params, x, y, mask)
    g_ref = jax.grad(ref_loss)(params, x, y, mask)
    for a, b in zip(g_pallas, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_train_step_decreases_loss():
    params = model.init_params()
    x, y, mask = synthetic_batch(2)
    losses = []
    for _ in range(60):
        params, loss = model.train_step(params, x, y, mask, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, f"no convergence: {losses[0]} -> {losses[-1]}"


def test_masked_rows_do_not_affect_loss():
    params = model.init_params()
    x, y, _ = synthetic_batch(3)
    mask_half = jnp.concatenate([jnp.ones(128), jnp.zeros(128)]).astype(jnp.float32)
    # Corrupt the masked-out rows wildly; loss must not change.
    x_bad = x.at[128:].set(99.0)
    y_bad = y.at[128:].set(-99.0)
    l1 = model.masked_mse(params, x, y, mask_half)
    l2 = model.masked_mse(params, x_bad, y_bad, mask_half)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_knn_score_flags_outliers():
    key = jax.random.PRNGKey(4)
    refs = jax.random.normal(key, (model.REFSET, model.FEATURES), jnp.float32)
    inliers = refs[: model.BATCH // 2] + 0.01
    outliers = jax.random.normal(key, (model.BATCH // 2, model.FEATURES)) * 8.0 + 30.0
    x = jnp.concatenate([inliers, outliers])
    scores = np.asarray(model.knn_score(x, refs))
    assert scores[: model.BATCH // 2].mean() * 10 < scores[model.BATCH // 2 :].mean()
    np.testing.assert_allclose(
        scores, ref.knn_score_ref(x, refs, model.KNN_K), rtol=1e-3, atol=1e-3
    )


def test_init_params_deterministic():
    a = model.init_params()
    b = model.init_params()
    for p, q in zip(a, b):
        np.testing.assert_array_equal(p, q)
